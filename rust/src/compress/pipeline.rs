//! The `Compressor` strategy subsystem (DESIGN.md §12).
//!
//! PR 3 turned three hard-coded networks into the composable
//! `net::topo::Topology` trait; this module does the same for
//! compression. Every Table-I method — and every new stage composition
//! the spec grammar (`compress::spec`) can name — is a [`Compressor`]:
//! per-node state (residual stores, DGC states, trailing layer stats)
//! plus two entry points, one per engine:
//!
//! * [`Compressor::sim_step`] — the accounting path (`exp::simrun`):
//!   exact wire/payload/density bookkeeping over the virtual net, no
//!   parameter updates.
//! * [`Compressor::train_reduce`] — the value-carrying path
//!   (`coordinator::Trainer`): reduce real gradients and update the
//!   parameters/optimizer.
//!
//! Both paths are **arena-threaded** (zero steady-state allocation in
//! the *transport* — `Arena::grows()` stays flat, DESIGN.md §9; the
//! per-node support-synthesis and `+tern` scratch still allocate
//! method-local buffers per step, exactly like the legacy DGC arm did)
//! and **executor-parallel** under the §4 bit-identical contract:
//! per-node state mutates only inside disjoint executor closures,
//! cross-node reductions happen on the coordinating thread in node
//! order. The five legacy `Method` values run
//! bit-identically to the pre-refactor engines
//! (`rust/tests/compressor_equivalence.rs` pins them against an inline
//! legacy reference, and the existing parallel/topology/fused
//! equivalence suites keep passing unchanged).
//!
//! Stage composition: a spec head picks the transport class, stages
//! plug in along it —
//!
//! ```text
//!   warmup schedule ──► threshold policy ──► scoring + selection ──► store ──► wire
//!   (Warmup)            (ThresholdPolicy:     (fuse::score_select_     (Residual-  (Topology::
//!                        fixed | layerwise |   compact / L1 kernel /    Store /     masked | sparse |
//!                        vargated)             Dgc top-k)               Dgc)        spread | dense)
//! ```
//!
//! so e.g. `dgc:layerwise` is the Eq. 4 threshold policy composed with
//! the per-node (DGC) transport, and `iwp:fixed+tern` appends ternary
//! quantization to the shared-mask payload. The parametric `+q:<bits>`
//! stage (DESIGN.md §17) generalizes that last hop: `+q:2` runs the
//! `+tern` machinery verbatim (it *is* `+tern`), while bf16/f16/q8/q4
//! ship [`QBlob`] payloads over the same mask-then-whole-blob shape.

use super::dgc::Dgc;
use super::fuse;
use super::importance::{LayerStats, EPS};
use super::quant::{QBlob, QuantWidth, QUANT_BLOCK};
use super::residual::ResidualStore;
use super::select;
use super::spec::{DgcSelect, IwpPolicy, MethodSpec, SpecHead};
use super::terngrad::{TernBlob, TernGrad};
use super::threshold::{ThresholdCfg, ThresholdPolicy};
use super::warmup::Warmup;
use crate::model::ParamLayout;
use crate::net::tuner::{Observation, Tuner, TunerMode, WirePick};
use crate::net::{RecoveryMode, RingNet, Topology, WireRing};
use crate::optim::MomentumSgd;
use crate::ring::{Arena, Executor};
use crate::runtime::ImportanceKernel;
use crate::sparse::{values_only_bytes, wire_bytes, BitMask, SparseVec, WireFormat};
use crate::util::rng::Rng;

/// What one compression + reduce step put on the wire — the engines
/// turn this into their accounting rows (`CompressionAccount`).
#[derive(Debug, Clone, Copy)]
pub struct WireOutcome {
    /// Mean wire bytes transmitted per node this step.
    pub wire_bytes_per_node: u64,
    /// Paper-metric payload bytes: `size[encode(sparse(G))]` per node.
    pub payload_bytes: u64,
    /// Transmitted gradient density this step.
    pub density: f64,
    /// Selected support size (own selection for per-node methods, the
    /// shared support for masked methods, the full coordinate count for
    /// dense paths) — the `CostModel` cross-validation input.
    pub support_nnz: u64,
    /// Virtual seconds the wire phase occupied (net-clock delta over
    /// this step's rounds, excluding the engines' compute gap) — equals
    /// the matching `CostModel` prediction bit-for-bit on a fresh clock.
    pub wire_seconds: f64,
}

/// Per-step context of the accounting engine (`exp::simrun::SimEngine`).
pub struct SimCtx<'a> {
    /// Epoch index of this step (drives warm-up / density schedules).
    pub epoch: usize,
    /// Ring size N (node *states* may be capped below this — see
    /// `SimEngine`'s exchangeable-node argument).
    pub nodes: usize,
    /// Model layout under simulation.
    pub layout: &'a ParamLayout,
    /// Synthetic weight buffer importance is scored against.
    pub weights: &'a [f32],
    /// Materialized per-node gradients (first `grads_needed` are live).
    pub grads: &'a [Vec<f32>],
    /// The virtual network (byte counters, clock).
    pub net: &'a mut RingNet,
    /// Communication topology of the reduce.
    pub topo: &'a dyn Topology,
    /// Node-parallel executor (§4 bit-identical contract).
    pub exec: &'a Executor,
    /// Staging arena for the transport hot paths.
    pub arena: &'a mut Arena,
    /// Per-node RNG streams (all N; streams beyond the materialized
    /// states feed exchangeable-support synthesis).
    pub rngs: &'a mut [Rng],
    /// Control stream (broadcaster draws, Alg. 1 line 6).
    pub ctl_rng: &'a mut Rng,
    /// Real socket ring (DESIGN.md §13). When set, every traveling
    /// payload — dense chunks, broadcaster masks, supports, ternary
    /// blobs — is encoded, spread over actual sockets, and only the
    /// *decoded* copy feeds the computation below, so the virtual
    /// accounting stays bit-identical iff the wire is faithful.
    /// On a v2 ring (DESIGN.md §16) "faithful" is enforced, not
    /// assumed: every frame carries a CRC trailer and injected wire
    /// faults are repaired by the per-edge ARQ before a payload ever
    /// reaches this seam, so the `.expect("wire … failed")` panics
    /// below only fire on *unrecoverable* schedules — their payload is
    /// the typed [`crate::net::WireError`] Display (e.g. `retry budget
    /// exhausted after 4 attempts`), which `main` maps to exit 3.
    pub wire: Option<&'a mut WireRing>,
    /// Online autotuner (DESIGN.md §14). When set, shared-mask
    /// pipelines feed it the observed support each step; in
    /// [`TunerMode::On`] the picked strategy executes instead of the
    /// configured static one, in [`TunerMode::LogOnly`] the pick is
    /// only recorded. Other pipelines ignore it (`Config::validate`
    /// rejects the flag combination up front).
    pub tuner: Option<&'a mut Tuner>,
}

/// Per-step context of the training engine (`coordinator::Trainer`).
pub struct TrainCtx<'a> {
    /// Epoch index of this step.
    pub epoch: usize,
    /// Learning rate at this step.
    pub lr: f32,
    /// Ring size N (== materialized node states in the trainer).
    pub nodes: usize,
    /// Model layout under training.
    pub layout: &'a ParamLayout,
    /// Flat parameter buffer (replicas are identical).
    pub params: &'a mut [f32],
    /// Per-node local gradients; dense reduces mutate them in place.
    pub grads: &'a mut [Vec<f32>],
    /// The virtual network.
    pub net: &'a mut RingNet,
    /// Communication topology of the reduce.
    pub topo: &'a dyn Topology,
    /// Node-parallel executor.
    pub exec: &'a Executor,
    /// Staging arena.
    pub arena: &'a mut Arena,
    /// Per-node RNG streams.
    pub node_rngs: &'a mut [Rng],
    /// Control stream (broadcaster draws).
    pub ctl_rng: &'a mut Rng,
    /// Global optimizer (momentum only on dense paths — Eq. 1 vs Eq. 3).
    pub opt: &'a mut MomentumSgd,
    /// The PJRT L1 importance kernel (loaded iff the spec scores with
    /// it — `MethodSpec::needs_kernel`).
    pub kernel: Option<&'a mut ImportanceKernel>,
    /// Online autotuner (DESIGN.md §14) — same contract as
    /// [`SimCtx::tuner`].
    pub tuner: Option<&'a mut Tuner>,
}

/// One compression pipeline: per-node state behind the two engine entry
/// points. See the module docs for the contract; build instances with
/// [`build`].
pub trait Compressor: Send {
    /// The validated spec this pipeline was built from.
    fn spec(&self) -> MethodSpec;

    /// How many of the engine's `materialized` per-node gradient
    /// buffers this step consumes (the 25M+-param fills dominate wall
    /// time, so engines only synthesize what the pipeline reads).
    fn grads_needed(&self, materialized: usize) -> usize;

    /// Accounting-only step over the virtual net (no value movement
    /// beyond what exact byte accounting needs).
    fn sim_step(&mut self, ctx: &mut SimCtx<'_>) -> WireOutcome;

    /// Value-carrying reduce + parameter update.
    fn train_reduce(&mut self, ctx: &mut TrainCtx<'_>) -> anyhow::Result<WireOutcome>;

    /// Node `node`'s accumulated pending update (importance-snapshot
    /// hook); `None` when the pipeline keeps no residual state.
    fn pending(&self, node: usize) -> Option<&[f32]>;

    /// Trailing per-layer importance stats (Eq. 4 controller input,
    /// Fig. 4 data); empty when the pipeline does not score.
    fn prev_stats(&self) -> &[LayerStats];

    /// Ring position `node` crashed: migrate its per-node state ahead
    /// of the survivor re-ring (elastic membership, DESIGN.md §15).
    /// `nodes_after` is the post-crash ring size and `states_after` the
    /// post-crash materialized state count (engines below their
    /// exchangeable-node cap keep the two equal).
    /// [`RecoveryMode::Handoff`] merges the departing node's pending
    /// store into its surviving ring successor;
    /// [`RecoveryMode::DropRescale`] drops it and rescales every
    /// survivor by `(nodes_after + 1) / nodes_after`. Stateless
    /// pipelines (dense, terngrad) carry no membership state — the
    /// default is a no-op.
    fn remove_node(
        &mut self,
        _node: usize,
        _mode: RecoveryMode,
        _nodes_after: usize,
        _states_after: usize,
    ) {
    }

    /// One fresh node joined at the end of the ring before `epoch`
    /// runs (DESIGN.md §15): its state starts zeroed (a join never
    /// resurrects stale residuals), and pipelines with a warm-up
    /// schedule re-enter it from `epoch` so the newcomer's empty store
    /// does not destabilize selection. Default: no-op.
    fn add_node(&mut self, _epoch: usize, _nodes_after: usize, _states_after: usize) {}

    /// Clone out node `node`'s residual store (state migration seam —
    /// the recovery-algebra suites rebuild a fresh smaller ring from
    /// exported survivor state). `None` for stateless pipelines.
    fn export_node(&self, _node: usize) -> Option<ResidualStore> {
        None
    }

    /// Install a residual store into node `node`'s state slot (the
    /// inverse of [`Compressor::export_node`]). No-op for stateless
    /// pipelines.
    fn install_node(&mut self, _node: usize, _store: ResidualStore) {}
}

/// Survivor re-ring over a vector of per-node residual stores
/// (DESIGN.md §15): remove `node`, then either hand its pending state
/// to its ring successor (the post-removal slot at `node % len`) or
/// rescale every survivor by `(nodes_after + 1) / nodes_after`. A
/// `node` beyond the materialized states (the accounting engine's
/// exchangeable cap) has no store to hand off — handoff is then a
/// no-op, while rescale still applies: the materialized stores stand
/// in for the full membership, so the expectation argument is
/// unchanged.
fn elastic_remove(
    stores: &mut Vec<ResidualStore>,
    node: usize,
    mode: RecoveryMode,
    nodes_after: usize,
) {
    if node < stores.len() {
        let departing = stores.remove(node);
        if mode == RecoveryMode::Handoff && !stores.is_empty() {
            let len = stores.len();
            stores[node % len].merge_from(&departing);
        }
    }
    if mode == RecoveryMode::DropRescale {
        let factor = (nodes_after + 1) as f32 / nodes_after as f32;
        for s in stores.iter_mut() {
            s.rescale(factor);
        }
    }
}

/// Grow (fresh zero state) or shrink a store vector to the
/// post-event materialized count.
fn resize_stores(stores: &mut Vec<ResidualStore>, states: usize, total: usize, momentum: f32) {
    while stores.len() < states {
        stores.push(ResidualStore::new(total, momentum));
    }
    stores.truncate(states);
}

/// Keep the fused fan-out scratch aligned with the store vector.
fn resize_scratch(scratch: &mut Vec<NodeScratch>, states: usize, total: usize, layers: usize) {
    while scratch.len() < states {
        scratch.extend(node_scratch(1, total, layers));
    }
    scratch.truncate(states);
}

/// Build-time knobs a pipeline draws from the engine's config (the
/// spec's stage overrides apply on top — see [`build`]).
#[derive(Debug, Clone, Copy)]
pub struct StageCfg {
    /// Ring size N.
    pub nodes: usize,
    /// Materialized node states (N for the trainer; `SimEngine` caps at
    /// its exchangeable-node limit).
    pub state_nodes: usize,
    /// Importance threshold (α for layer-adaptive policies).
    pub threshold: f32,
    /// Eq. 4 dispersion gain β.
    pub beta: f32,
    /// Eq. 4 crossover C.
    pub c: f32,
    /// Number of random mask-broadcast nodes r (Alg. 1).
    pub mask_nodes: usize,
    /// Randomized selection default (spec `+sel`/`+nosel` overrides).
    pub random_select: bool,
    /// Residual-store momentum (spec `+nomcorr` zeroes it).
    pub momentum: f32,
    /// DGC baseline per-node density.
    pub dgc_density: f64,
    /// Warm-up epochs default (spec `+warmup:<e>` overrides).
    pub warmup_epochs: usize,
}

impl StageCfg {
    fn effective_warmup(&self, spec: &MethodSpec) -> (usize, Warmup) {
        let epochs = spec.warmup.unwrap_or(self.warmup_epochs);
        let warmup = if epochs > 0 {
            Warmup {
                epochs,
                start_mult: 0.1,
            }
        } else {
            Warmup::none()
        };
        (epochs, warmup)
    }

    fn store_momentum(&self, spec: &MethodSpec) -> f32 {
        if spec.mcorr == Some(false) {
            0.0
        } else {
            self.momentum
        }
    }
}

/// Build the [`Compressor`] a validated spec names, with per-node state
/// sized for `cfg.state_nodes`.
pub fn build(spec: MethodSpec, cfg: &StageCfg, layout: &ParamLayout) -> Box<dyn Compressor> {
    match spec.head {
        SpecHead::Dense => Box::new(DenseCompressor { spec }),
        SpecHead::Terngrad => Box::new(TernaryCompressor { spec }),
        SpecHead::Iwp(policy) => Box::new(SharedMaskCompressor::new(spec, policy, cfg, layout)),
        SpecHead::Dgc(sel) => Box::new(PerNodeCompressor::new(spec, sel, cfg, layout)),
    }
}

/// Reusable per-node slot for the fused scoring fan-outs (DESIGN.md
/// §11): a cloned RNG stream, the node's selection mask, and its
/// per-layer stats rows. `bcast` marks shared-mask broadcasters.
struct NodeScratch {
    bcast: bool,
    rng: Rng,
    mask: BitMask,
    stats: Vec<LayerStats>,
}

fn node_scratch(n: usize, total: usize, layers: usize) -> Vec<NodeScratch> {
    (0..n)
        .map(|_| NodeScratch {
            bcast: false,
            rng: Rng::new(0),
            mask: BitMask::zeros(total),
            stats: Vec::with_capacity(layers),
        })
        .collect()
}

/// Exchangeable stand-in supports for the node states beyond the
/// accounting engine's materialized cap: one random k-subset per
/// remaining RNG stream (supports across disjoint data shards are
/// near-independent — the same assumption behind the paper's 1%->2%
/// worst-case argument). Shared by both `dgc:*` selection variants.
fn exchangeable_supports(
    exec: &Executor,
    rngs: &mut [Rng],
    k: usize,
    total: usize,
) -> Vec<BitMask> {
    exec.map_mut(rngs, |_, rng| {
        let mut m = BitMask::zeros(total);
        for _ in 0..k {
            m.set(rng.below(total));
        }
        m
    })
}

// ---- dense (baseline) --------------------------------------------------

/// `dense`: synchronous SGD, full gradients on the wire.
struct DenseCompressor {
    spec: MethodSpec,
}

impl Compressor for DenseCompressor {
    fn spec(&self) -> MethodSpec {
        self.spec
    }

    fn grads_needed(&self, _materialized: usize) -> usize {
        0
    }

    fn sim_step(&mut self, ctx: &mut SimCtx<'_>) -> WireOutcome {
        // Account-only dense rounds under the configured topology
        // (moving 61M f32 per node through the data path buys nothing
        // here; bytes are exact). total/N is the exact per-node mean —
        // for the flat ring it equals the paper's 2(N-1)/N · V
        // reference.
        let t0 = ctx.net.clock();
        let total = match ctx.wire.as_deref_mut() {
            // Wire path: the weight buffer allgathers in real chunks
            // around the socket ring; the *decoded* coordinate count
            // (== total iff codec and relay are faithful) drives the
            // accounting.
            Some(w) => w.exchange_dense(ctx.weights).expect("wire dense exchange failed"),
            None => ctx.layout.total_params(),
        };
        let rep = ctx.topo.dense_bytes_only(ctx.net, total, ctx.arena);
        WireOutcome {
            wire_bytes_per_node: rep.total_bytes() / ctx.nodes as u64,
            payload_bytes: ctx.layout.dense_bytes(),
            density: 1.0,
            support_nnz: total as u64,
            wire_seconds: ctx.net.clock() - t0,
        }
    }

    fn train_reduce(&mut self, ctx: &mut TrainCtx<'_>) -> anyhow::Result<WireOutcome> {
        let t0 = ctx.net.clock();
        let rep = ctx.topo.dense(ctx.net, ctx.grads, ctx.exec, ctx.arena);
        let n = ctx.nodes as f32;
        // grads[0] now holds the sum; the optimizer averages inline (one
        // pass, no materialized average buffer — bit-identical).
        ctx.opt.step_mean(ctx.params, &ctx.grads[0], n, ctx.lr);
        Ok(WireOutcome {
            wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
            payload_bytes: ctx.layout.dense_bytes(),
            density: 1.0,
            support_nnz: ctx.layout.total_params() as u64,
            wire_seconds: ctx.net.clock() - t0,
        })
    }

    fn pending(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    fn prev_stats(&self) -> &[LayerStats] {
        &[]
    }
}

// ---- terngrad ----------------------------------------------------------

/// `terngrad`: per-layer ternary quantization, blobs spread whole.
struct TernaryCompressor {
    spec: MethodSpec,
}

impl Compressor for TernaryCompressor {
    fn spec(&self) -> MethodSpec {
        self.spec
    }

    fn grads_needed(&self, materialized: usize) -> usize {
        // Blob sizes are shape-determined, so one representative
        // encoding prices every node's blob.
        materialized.min(1)
    }

    fn sim_step(&mut self, ctx: &mut SimCtx<'_>) -> WireOutcome {
        let t0 = ctx.net.clock();
        let n = ctx.nodes;
        let t = TernGrad::encode(&ctx.grads[0], ctx.layout, &mut ctx.rngs[0]);
        // Wire path: the representative blob spreads over real
        // sockets; its decoded shape prices every node's blob.
        let t = match ctx.wire.as_deref_mut() {
            Some(w) => w.spread_tern_grad(&t).expect("wire ternary spread failed"),
            None => t,
        };
        let blob = t.wire_bytes();
        // Ternary values are not closed under addition, so no topology
        // can scatter-REDUCE them — the quantized blobs must spread
        // whole (every blob to every node). This is why quantization
        // alone does not help rings (the paper's Sec. II point); the
        // payload ratio below is TernGrad's native parameter-server
        // number.
        let rep = ctx.topo.spread_bytes(ctx.net, blob, n, ctx.arena);
        WireOutcome {
            wire_bytes_per_node: rep.total_bytes() / n as u64,
            payload_bytes: blob,
            density: 1.0,
            support_nnz: ctx.layout.total_params() as u64,
            wire_seconds: ctx.net.clock() - t0,
        }
    }

    fn train_reduce(&mut self, ctx: &mut TrainCtx<'_>) -> anyhow::Result<WireOutcome> {
        let t0 = ctx.net.clock();
        let n = ctx.nodes;
        // Encode per node in parallel (each node consumes only its own
        // RNG stream), then decode + sum sequentially in node order —
        // the same f32 addition order as the sequential loop — and
        // spread the quantized blobs over the configured topology.
        let encoded: Vec<TernGrad> = {
            let grads: &[Vec<f32>] = ctx.grads;
            let layout = ctx.layout;
            ctx.exec.map_mut(ctx.node_rngs, |node, rng| {
                TernGrad::encode(&grads[node], layout, rng)
            })
        };
        let mut sum = vec![0.0f32; ctx.layout.total_params()];
        for t in &encoded {
            for (s, v) in sum.iter_mut().zip(t.decode(ctx.layout)) {
                *s += v;
            }
        }
        let rep = ctx
            .topo
            .spread_bytes(ctx.net, encoded[0].wire_bytes(), n, ctx.arena);
        ctx.opt.step_mean(ctx.params, &sum, n as f32, ctx.lr);
        Ok(WireOutcome {
            wire_bytes_per_node: rep.total_bytes() / n as u64,
            payload_bytes: encoded[0].wire_bytes(),
            density: 1.0,
            support_nnz: ctx.layout.total_params() as u64,
            wire_seconds: ctx.net.clock() - t0,
        })
    }

    fn pending(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    fn prev_stats(&self) -> &[LayerStats] {
        &[]
    }
}

// ---- shared-mask (IWP family) ------------------------------------------

/// `iwp:*`: importance scoring × threshold policy × randomized
/// broadcaster masks × residual store, over the shared-mask (Alg. 1)
/// transport — optionally quantizing the compacted payload
/// (`+tern`/`+q:<bits>`, DESIGN.md §17).
struct SharedMaskCompressor {
    spec: MethodSpec,
    policy: ThresholdPolicy,
    warmup: Warmup,
    /// Epoch the warm-up schedule (re)started at — 0 until a mid-epoch
    /// join re-enters warm-up (DESIGN.md §15).
    epoch_base: usize,
    random_select: bool,
    mask_nodes: usize,
    stores: Vec<ResidualStore>,
    prev_stats: Vec<LayerStats>,
    thrs_buf: Vec<f32>,
    /// Sim-side fused fan-out slots (cloned-out RNGs, masks, stats).
    scratch: Vec<NodeScratch>,
    /// Train-side kernel scratch, allocated on first `train_reduce`
    /// (the accounting engine must not pay a model-sized `u` buffer).
    u_buf: Vec<f32>,
    mask_slots: Vec<BitMask>,
    stats_scratch: Vec<LayerStats>,
    /// Per-node compacted payloads for the whole-blob wire formats
    /// (`+tern`/`+q:<bits>`, and the tuner's gather/quant picks) —
    /// train side, lazy.
    tern_payloads: Vec<Vec<f32>>,
    /// All-ones mask for the tuner's dense-pick residual flush
    /// (`clear_masked` over the full support; lazy — `take_all` would
    /// allocate a model-sized Vec per node per step).
    full_mask: BitMask,
}

impl SharedMaskCompressor {
    fn new(spec: MethodSpec, policy: IwpPolicy, cfg: &StageCfg, layout: &ParamLayout) -> Self {
        let total = layout.total_params();
        let policy = match policy {
            IwpPolicy::Fixed => ThresholdPolicy::Fixed(cfg.threshold),
            IwpPolicy::Layerwise => ThresholdPolicy::Layerwise(ThresholdCfg {
                alpha: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                ..Default::default()
            }),
            IwpPolicy::VarGate { gate, boost } => ThresholdPolicy::VarGated {
                alpha: cfg.threshold,
                gate,
                boost,
            },
        };
        let (_, warmup) = cfg.effective_warmup(&spec);
        SharedMaskCompressor {
            policy,
            warmup,
            epoch_base: 0,
            random_select: spec.random_select.unwrap_or(cfg.random_select),
            mask_nodes: cfg.mask_nodes,
            stores: (0..cfg.state_nodes)
                .map(|_| ResidualStore::new(total, cfg.store_momentum(&spec)))
                .collect(),
            prev_stats: vec![LayerStats::default(); layout.n_layers()],
            thrs_buf: Vec::with_capacity(layout.n_layers()),
            scratch: node_scratch(cfg.state_nodes, total, layout.n_layers()),
            u_buf: Vec::new(),
            mask_slots: Vec::new(),
            stats_scratch: Vec::new(),
            tern_payloads: Vec::new(),
            full_mask: BitMask::zeros(0),
            spec,
        }
    }

    fn ensure_train_scratch(&mut self, total: usize, layers: usize) {
        if self.u_buf.len() != total {
            self.u_buf = vec![1.0; total];
        }
        let k = self.mask_nodes.min(self.stores.len());
        if self.mask_slots.len() != k {
            self.mask_slots = (0..k).map(|_| BitMask::zeros(total)).collect();
        }
        if self.stats_scratch.len() != layers {
            self.stats_scratch = vec![LayerStats::default(); layers];
        }
    }

    /// Mask spread + whole-blob spread of the `+tern` stage: OR the
    /// broadcaster masks locally, spread them, then spread every node's
    /// ternary-encoded compacted payload (not closed under addition —
    /// no scatter-reduce). Returns `(shared, blob_bytes, total_bytes)`.
    /// On the wire path a support-shaped blob spreads over real
    /// sockets and its *decoded* length prices the blobs.
    fn tern_wire(
        &self,
        ctx_net: &mut RingNet,
        topo: &dyn Topology,
        arena: &mut Arena,
        wire: Option<&mut WireRing>,
        mask_refs: &[&BitMask],
        nodes: usize,
        total: usize,
    ) -> (BitMask, u64, u64) {
        let mut shared = BitMask::zeros(total);
        for m in mask_refs {
            shared.or_assign(m);
        }
        let rep_mask = topo.spread_bytes(ctx_net, shared.wire_bytes(), mask_refs.len(), arena);
        let nnz = match wire {
            Some(w) => {
                let probe = TernBlob {
                    len: shared.count(),
                    scale: 0.0,
                    codes: vec![0u8; shared.count().div_ceil(4)],
                };
                w.spread_tern_blob(&probe)
                    .expect("wire ternary blob spread failed")
                    .len
            }
            None => shared.count(),
        };
        let blob = TernBlob::wire_bytes_for(nnz);
        let rep_blob = topo.spread_bytes(ctx_net, blob, nodes, arena);
        (shared, blob, rep_mask.total_bytes() + rep_blob.total_bytes())
    }

    /// The `+q:<bits>` analogue of [`Self::tern_wire`] for the non-2-bit
    /// widths: mask spread, then every node's [`QBlob`]-encoded
    /// compacted payload spreads whole (like `+tern`, quantized grids
    /// are not closed under addition). On the wire path a shape-exact
    /// probe blob spreads over real sockets and its *decoded* length
    /// prices the blobs.
    #[allow(clippy::too_many_arguments)]
    fn q_wire(
        &self,
        ctx_net: &mut RingNet,
        topo: &dyn Topology,
        arena: &mut Arena,
        wire: Option<&mut WireRing>,
        mask_refs: &[&BitMask],
        nodes: usize,
        total: usize,
        width: QuantWidth,
    ) -> (BitMask, u64, u64) {
        let mut shared = BitMask::zeros(total);
        for m in mask_refs {
            shared.or_assign(m);
        }
        let rep_mask = topo.spread_bytes(ctx_net, shared.wire_bytes(), mask_refs.len(), arena);
        let nnz = match wire {
            Some(w) => {
                let count = shared.count();
                let probe = QBlob {
                    width,
                    len: count,
                    block: if width.is_float() { 0 } else { QUANT_BLOCK },
                    scales: vec![0.0; width.scale_slots(count)],
                    codes: vec![0u8; width.code_bytes(count)],
                };
                w.spread_q_blob(&probe)
                    .expect("wire quant blob spread failed")
                    .len
            }
            None => shared.count(),
        };
        let blob = QBlob::wire_bytes_for(nnz, width);
        let rep_blob = topo.spread_bytes(ctx_net, blob, nodes, arena);
        (shared, blob, rep_mask.total_bytes() + rep_blob.total_bytes())
    }
}

impl Compressor for SharedMaskCompressor {
    fn spec(&self) -> MethodSpec {
        self.spec
    }

    fn grads_needed(&self, materialized: usize) -> usize {
        materialized
    }

    fn sim_step(&mut self, ctx: &mut SimCtx<'_>) -> WireOutcome {
        let t0 = ctx.net.clock();
        let total = ctx.layout.total_params();
        let sim_nodes = self.stores.len();
        // Warm-up (and every epoch-driven schedule) counts from the
        // last warm-up (re)entry — identical to the raw epoch until a
        // join rebases it (DESIGN.md §15).
        let eff_epoch = ctx.epoch.saturating_sub(self.epoch_base);
        let wmult = self.warmup.multiplier(eff_epoch);
        self.policy.layer_thresholds_into(
            ctx.layout,
            &self.prev_stats,
            eff_epoch,
            wmult,
            &mut self.thrs_buf,
        );
        // Broadcasters drawn from the materialized (exchangeable) node
        // states (Alg. 1 line 6).
        let broadcasters = ctx
            .ctl_rng
            .choose_distinct(sim_nodes, self.mask_nodes.min(sim_nodes));
        // Fused single-pass fan-out (DESIGN.md §11): every node folds
        // its gradient into its residual store; broadcaster nodes
        // additionally score, select, and pack their mask in the *same*
        // sweep. Broadcaster RNG streams are cloned out and written
        // back, so cross-step evolution matches the multi-pass
        // reference exactly.
        for scr in self.scratch.iter_mut() {
            scr.bcast = false;
        }
        for &b in &broadcasters {
            self.scratch[b].bcast = true;
            self.scratch[b].rng = ctx.rngs[b].clone();
        }
        {
            let grads = ctx.grads;
            let weights = ctx.weights;
            let layout = ctx.layout;
            let thrs: &[f32] = &self.thrs_buf;
            let random_select = self.random_select;
            ctx.exec.map_mut2(
                &mut self.stores,
                &mut self.scratch,
                |node, store, scr| {
                    if scr.bcast {
                        fuse::score_select_compact(
                            layout,
                            thrs,
                            weights,
                            &grads[node],
                            EPS,
                            random_select,
                            &mut scr.rng,
                            store,
                            &mut scr.mask,
                            &mut scr.stats,
                        );
                    } else {
                        store.accumulate(&grads[node]);
                    }
                },
            );
        }
        // Write RNG streams back and merge stats in broadcaster order
        // (the same f64 addition order as the reference).
        for s in self.prev_stats.iter_mut() {
            *s = LayerStats::default();
        }
        for &b in &broadcasters {
            ctx.rngs[b] = self.scratch[b].rng.clone();
            for (li, st) in self.scratch[b].stats.iter().enumerate() {
                self.prev_stats[li].merge(st);
            }
        }
        // Wire path: each broadcaster's mask spreads over real sockets
        // (Alg. 1's mask AllGather) and the *decoded* copies feed the
        // OR, the byte accounting, and the residual clear below — a
        // codec bit flip would corrupt the shared support and diverge
        // every subsequent step.
        let decoded_masks: Option<Vec<BitMask>> = ctx.wire.as_deref_mut().map(|w| {
            broadcasters
                .iter()
                .map(|&b| {
                    w.spread_mask(b, &self.scratch[b].mask)
                        .expect("wire mask spread failed")
                })
                .collect()
        });
        let mask_refs: Vec<&BitMask> = match &decoded_masks {
            Some(ms) => ms.iter().collect(),
            None => broadcasters
                .iter()
                .map(|&b| &self.scratch[b].mask)
                .collect(),
        };
        // Autotuner seam (DESIGN.md §14): OR the (decoded) broadcaster
        // masks into the observation and price the strategy grid. Pure
        // data in, pure decision out — the masks already traveled
        // above, so the decision is identical across transports. In
        // log-only mode the decision is traced and the static strategy
        // below runs untouched (bit-identical to tuner-off).
        let tuned_pick: Option<usize> = match ctx.tuner.as_deref_mut() {
            Some(tuner) => {
                let mut shared_obs = BitMask::zeros(total);
                for m in &mask_refs {
                    shared_obs.or_assign(m);
                }
                let d = tuner.decide(&Observation {
                    coords: total,
                    k: mask_refs.len(),
                    shared: &shared_obs,
                });
                (tuner.mode() == TunerMode::On).then_some(d.index)
            }
            None => None,
        };
        if let Some(idx) = tuned_pick {
            // Execute the picked strategy. Masked picks run through
            // their prebuilt pipelined topology (selection prep charged
            // on the clock internally); the other formats charge the
            // same prep up front (`net.advance`) — the prep-inclusive
            // objective every candidate was priced under.
            let tuner = ctx.tuner.as_deref().expect("pick implies a tuner");
            let strat = *tuner.strategy(idx);
            let topo = tuner.strategy_topo(idx);
            let outcome = match strat.wire {
                WirePick::Masked => {
                    let (shared, rep) = topo.masked_bytes_only(ctx.net, &mask_refs, ctx.arena);
                    let nnz = shared.count();
                    let shared_ref = &shared;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(shared_ref);
                    });
                    WireOutcome {
                        wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                        payload_bytes: wire_bytes(WireFormat::cheapest(total, nnz), total, nnz),
                        density: shared.density(),
                        support_nnz: nnz as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Dense => {
                    // The full pending residual flushes dense (the
                    // wire path exchanges real chunks, like the dense
                    // pipeline's accounting step).
                    let decoded = match ctx.wire.as_deref_mut() {
                        Some(w) => {
                            w.exchange_dense(ctx.weights).expect("wire dense exchange failed")
                        }
                        None => total,
                    };
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let rep = topo.dense_bytes_only(ctx.net, decoded, ctx.arena);
                    if self.full_mask.len() != total {
                        let mut m = BitMask::zeros(total);
                        for i in 0..total {
                            m.set(i);
                        }
                        self.full_mask = m;
                    }
                    let full = &self.full_mask;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(full);
                    });
                    WireOutcome {
                        wire_bytes_per_node: rep.total_bytes() / ctx.nodes as u64,
                        payload_bytes: ctx.layout.dense_bytes(),
                        density: 1.0,
                        support_nnz: decoded as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Gather => {
                    // Sparse allgather: masks, then every node's whole
                    // f32 blob (4·nnz — the blob size is fully
                    // determined by the decoded shared mask, so the
                    // virtual pricing needs no extra socket traffic).
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let mut shared = BitMask::zeros(total);
                    for m in &mask_refs {
                        shared.or_assign(m);
                    }
                    let rep_mask = topo.spread_bytes(
                        ctx.net,
                        shared.wire_bytes(),
                        mask_refs.len(),
                        ctx.arena,
                    );
                    let nnz = shared.count();
                    let blob = values_only_bytes(nnz);
                    let rep_blob = topo.spread_bytes(ctx.net, blob, ctx.nodes, ctx.arena);
                    let shared_ref = &shared;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(shared_ref);
                    });
                    WireOutcome {
                        wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                            / ctx.nodes as u64,
                        payload_bytes: blob,
                        density: shared.density(),
                        support_nnz: nnz as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Tern => {
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let (shared, blob, total_bytes) = self.tern_wire(
                        ctx.net,
                        topo,
                        ctx.arena,
                        ctx.wire.as_deref_mut(),
                        &mask_refs,
                        ctx.nodes,
                        total,
                    );
                    let shared_ref = &shared;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(shared_ref);
                    });
                    WireOutcome {
                        wire_bytes_per_node: total_bytes / ctx.nodes as u64,
                        payload_bytes: blob,
                        density: shared.density(),
                        support_nnz: shared.count() as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Quant(width) => {
                    // The `+q:<bits>` stage body over the picked
                    // topology (the tuner prices precision against
                    // bandwidth; the 2-bit point is the Tern pick).
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let (shared, blob, total_bytes) = self.q_wire(
                        ctx.net,
                        topo,
                        ctx.arena,
                        ctx.wire.as_deref_mut(),
                        &mask_refs,
                        ctx.nodes,
                        total,
                        width,
                    );
                    let shared_ref = &shared;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(shared_ref);
                    });
                    WireOutcome {
                        wire_bytes_per_node: total_bytes / ctx.nodes as u64,
                        payload_bytes: blob,
                        density: shared.density(),
                        support_nnz: shared.count() as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
            };
            return outcome;
        }
        let (shared, wire, payload) = match self.spec.quant {
            // `+tern` ≡ `+q:2`: the 2-bit width runs the historical
            // TernBlob path verbatim (same frames, same closed forms).
            Some(QuantWidth::Q2) => {
                let (shared, blob, total_bytes) = self.tern_wire(
                    ctx.net,
                    ctx.topo,
                    ctx.arena,
                    ctx.wire.as_deref_mut(),
                    &mask_refs,
                    ctx.nodes,
                    total,
                );
                (shared, total_bytes / ctx.nodes as u64, blob)
            }
            Some(width) => {
                let (shared, blob, total_bytes) = self.q_wire(
                    ctx.net,
                    ctx.topo,
                    ctx.arena,
                    ctx.wire.as_deref_mut(),
                    &mask_refs,
                    ctx.nodes,
                    total,
                    width,
                );
                (shared, total_bytes / ctx.nodes as u64, blob)
            }
            None => {
                let (shared, rep) = ctx.topo.masked_bytes_only(ctx.net, &mask_refs, ctx.arena);
                let nnz = shared.count();
                let payload = wire_bytes(WireFormat::cheapest(total, nnz), total, nnz);
                (shared, rep.mean_bytes_per_node() as u64, payload)
            }
        };
        // Fused residual take: zero residual + velocity on the shared
        // support in one sweep, no per-node Vec (the accounting engine
        // discards the transmitted values).
        let shared_ref = &shared;
        ctx.exec.map_mut(&mut self.stores, |_, store| {
            store.clear_masked(shared_ref);
        });
        WireOutcome {
            wire_bytes_per_node: wire,
            payload_bytes: payload,
            density: shared.density(),
            support_nnz: shared.count() as u64,
            wire_seconds: ctx.net.clock() - t0,
        }
    }

    fn train_reduce(&mut self, ctx: &mut TrainCtx<'_>) -> anyhow::Result<WireOutcome> {
        let t0 = ctx.net.clock();
        let n = ctx.nodes;
        let total = ctx.layout.total_params();
        // Residual accumulation (momentum correction) on every node,
        // fanned out across the executor (disjoint per-node stores).
        {
            let grads: &[Vec<f32>] = ctx.grads;
            ctx.exec.map_mut(&mut self.stores, |node, store| {
                store.accumulate(&grads[node]);
            });
        }

        // Per-layer thresholds from trailing stats, refilled into the
        // reusable table. Epoch counts from the last warm-up (re)entry
        // (DESIGN.md §15).
        let eff_epoch = ctx.epoch.saturating_sub(self.epoch_base);
        let wmult = self.warmup.multiplier(eff_epoch);
        self.policy.layer_thresholds_into(
            ctx.layout,
            &self.prev_stats,
            eff_epoch,
            wmult,
            &mut self.thrs_buf,
        );

        // Random broadcaster nodes (Alg. 1 line 6).
        let broadcasters = ctx.ctl_rng.choose_distinct(n, self.mask_nodes.min(n));
        self.ensure_train_scratch(total, ctx.layout.n_layers());

        // Each broadcaster scores its pending residuals with the L1
        // kernel, layer by layer, packing selection bits straight into
        // a reusable model-wide mask slot (DESIGN.md §11). This loop
        // stays sequential: the PJRT kernel executes through a single
        // loaded artifact handle. Stats accumulate in a scratch buffer
        // so a kernel error mid-loop leaves `prev_stats` untouched.
        for s in self.stats_scratch.iter_mut() {
            *s = LayerStats::default();
        }
        let kernel = ctx
            .kernel
            .as_mut()
            .expect("shared-mask specs always load the kernel");
        for (bi, &b) in broadcasters.iter().enumerate() {
            select::fill_u(&mut ctx.node_rngs[b], self.random_select, &mut self.u_buf);
            let pending = self.stores[b].pending();
            let weights: &[f32] = ctx.params;
            let mask = &mut self.mask_slots[bi];
            mask.clear_all();
            for (li, layer) in ctx.layout.layers().iter().enumerate() {
                let r = layer.range();
                let st = kernel.score_into(
                    &pending[r.clone()],
                    &weights[r.clone()],
                    &self.u_buf[r.clone()],
                    self.thrs_buf[li],
                    EPS,
                    r.start,
                    mask,
                )?;
                self.stats_scratch[li].merge(&st);
            }
        }
        std::mem::swap(&mut self.prev_stats, &mut self.stats_scratch);

        let inv_n = 1.0 / n as f32;
        // Autotuner seam (DESIGN.md §14), mirroring `sim_step`: OR the
        // broadcaster masks, price the grid, and in On mode execute the
        // pick. Decisions are computed on the coordinating thread from
        // pure data, so they are identical at any `--parallelism`.
        let tuned_pick: Option<usize> = match ctx.tuner.as_deref_mut() {
            Some(tuner) => {
                let mut shared_obs = BitMask::zeros(total);
                for m in &self.mask_slots[..broadcasters.len()] {
                    shared_obs.or_assign(m);
                }
                let d = tuner.decide(&Observation {
                    coords: total,
                    k: broadcasters.len(),
                    shared: &shared_obs,
                });
                (tuner.mode() == TunerMode::On).then_some(d.index)
            }
            None => None,
        };
        if let Some(idx) = tuned_pick {
            let tuner = ctx.tuner.as_deref().expect("pick implies a tuner");
            let strat = *tuner.strategy(idx);
            let topo = tuner.strategy_topo(idx);
            let outcome = match strat.wire {
                WirePick::Masked => {
                    // Alg. 1 over the picked (pipelined) topology.
                    let mask_refs: Vec<&BitMask> =
                        self.mask_slots[..broadcasters.len()].iter().collect();
                    let values: Vec<&[f32]> =
                        self.stores.iter().map(|s| s.pending()).collect();
                    let (shared, summed, rep) =
                        topo.masked(ctx.net, &mask_refs, &values, ctx.exec, ctx.arena);
                    let shared_ref = &shared;
                    ctx.exec.map_mut(&mut self.stores, |_, store| {
                        store.clear_masked(shared_ref);
                    });
                    ctx.opt
                        .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
                    let nnz = shared.count();
                    WireOutcome {
                        wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                        payload_bytes: wire_bytes(WireFormat::cheapest(total, nnz), total, nnz),
                        density: shared.density(),
                        support_nnz: nnz as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Dense => {
                    // Flush the full pending residual dense. The update
                    // stays the masked paths' plain sparse-SGD rule
                    // (momentum lives in the residual stores, Eq. 3),
                    // applied over the full support.
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let mut bufs: Vec<Vec<f32>> =
                        ctx.exec.map_mut(&mut self.stores, |_, store| store.take_all());
                    let rep = topo.dense(ctx.net, &mut bufs, ctx.exec, ctx.arena);
                    if self.full_mask.len() != total {
                        let mut m = BitMask::zeros(total);
                        for i in 0..total {
                            m.set(i);
                        }
                        self.full_mask = m;
                    }
                    ctx.opt.step_sparse_mask(
                        ctx.params,
                        &self.full_mask,
                        &bufs[0],
                        inv_n,
                        ctx.lr,
                    );
                    WireOutcome {
                        wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                        payload_bytes: ctx.layout.dense_bytes(),
                        density: 1.0,
                        support_nnz: total as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Gather => {
                    // Sparse allgather: per-node compacted payloads
                    // travel whole; receivers sum in node order.
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let mut shared = BitMask::zeros(total);
                    for m in &self.mask_slots[..broadcasters.len()] {
                        shared.or_assign(m);
                    }
                    if self.tern_payloads.len() != self.stores.len() {
                        self.tern_payloads = vec![Vec::new(); self.stores.len()];
                    }
                    let shared_ref = &shared;
                    ctx.exec.map_mut2(
                        &mut self.stores,
                        &mut self.tern_payloads,
                        |_, store, buf| {
                            fuse::take_compact(store, shared_ref, buf);
                        },
                    );
                    let rep_mask = topo.spread_bytes(
                        ctx.net,
                        shared.wire_bytes(),
                        broadcasters.len(),
                        ctx.arena,
                    );
                    let blob = values_only_bytes(shared.count());
                    let rep_blob = topo.spread_bytes(ctx.net, blob, n, ctx.arena);
                    let mut summed = vec![0.0f32; shared.count()];
                    for p in &self.tern_payloads {
                        for (s, v) in summed.iter_mut().zip(p) {
                            *s += v;
                        }
                    }
                    ctx.opt
                        .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
                    WireOutcome {
                        wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                            / n as u64,
                        payload_bytes: blob,
                        density: shared.density(),
                        support_nnz: shared.count() as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Tern => {
                    // The `+tern` stage body over the picked topology.
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let mut shared = BitMask::zeros(total);
                    for m in &self.mask_slots[..broadcasters.len()] {
                        shared.or_assign(m);
                    }
                    if self.tern_payloads.len() != self.stores.len() {
                        self.tern_payloads = vec![Vec::new(); self.stores.len()];
                    }
                    let shared_ref = &shared;
                    ctx.exec.map_mut2(
                        &mut self.stores,
                        &mut self.tern_payloads,
                        |_, store, buf| {
                            fuse::take_compact(store, shared_ref, buf);
                        },
                    );
                    let blobs: Vec<TernBlob> = {
                        let payloads: &[Vec<f32>] = &self.tern_payloads;
                        ctx.exec.map_mut(ctx.node_rngs, |node, rng| {
                            TernBlob::encode(&payloads[node], rng)
                        })
                    };
                    let rep_mask = topo.spread_bytes(
                        ctx.net,
                        shared.wire_bytes(),
                        broadcasters.len(),
                        ctx.arena,
                    );
                    let rep_blob =
                        topo.spread_bytes(ctx.net, blobs[0].wire_bytes(), n, ctx.arena);
                    let mut summed = vec![0.0f32; shared.count()];
                    for b in &blobs {
                        b.add_decoded_into(&mut summed);
                    }
                    ctx.opt
                        .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
                    WireOutcome {
                        wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                            / n as u64,
                        payload_bytes: blobs[0].wire_bytes(),
                        density: shared.density(),
                        support_nnz: shared.count() as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
                WirePick::Quant(width) => {
                    // The `+q:<bits>` stage body over the picked
                    // topology: fused take + compact, parallel QBlob
                    // encode, mask + whole-blob spreads, decode-sum.
                    ctx.net.advance(crate::net::topo::pipeline::prep_seconds(total));
                    let mut shared = BitMask::zeros(total);
                    for m in &self.mask_slots[..broadcasters.len()] {
                        shared.or_assign(m);
                    }
                    if self.tern_payloads.len() != self.stores.len() {
                        self.tern_payloads = vec![Vec::new(); self.stores.len()];
                    }
                    let shared_ref = &shared;
                    ctx.exec.map_mut2(
                        &mut self.stores,
                        &mut self.tern_payloads,
                        |_, store, buf| {
                            fuse::take_compact(store, shared_ref, buf);
                        },
                    );
                    let blobs: Vec<QBlob> = {
                        let payloads: &[Vec<f32>] = &self.tern_payloads;
                        ctx.exec.map_mut(ctx.node_rngs, |node, rng| {
                            QBlob::encode(&payloads[node], width, rng)
                        })
                    };
                    let rep_mask = topo.spread_bytes(
                        ctx.net,
                        shared.wire_bytes(),
                        broadcasters.len(),
                        ctx.arena,
                    );
                    let rep_blob =
                        topo.spread_bytes(ctx.net, blobs[0].wire_bytes(), n, ctx.arena);
                    let mut summed = vec![0.0f32; shared.count()];
                    for b in &blobs {
                        b.add_decoded_into(&mut summed);
                    }
                    ctx.opt
                        .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
                    WireOutcome {
                        wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                            / n as u64,
                        payload_bytes: blobs[0].wire_bytes(),
                        density: shared.density(),
                        support_nnz: shared.count() as u64,
                        wire_seconds: ctx.net.clock() - t0,
                    }
                }
            };
            return Ok(outcome);
        }
        let outcome = if self.spec.quant == Some(QuantWidth::Q2) {
            // `+tern` ≡ `+q:2`: once the shared mask is known, each
            // node's compacted residuals quantize ternary and spread
            // whole (not closed under addition), decode-summing at full
            // precision on every node.
            let mask_refs: Vec<&BitMask> =
                self.mask_slots[..broadcasters.len()].iter().collect();
            let mut shared = BitMask::zeros(total);
            for m in &mask_refs {
                shared.or_assign(m);
            }
            // Fused take + compact per node (momentum factor masking).
            if self.tern_payloads.len() != self.stores.len() {
                self.tern_payloads = vec![Vec::new(); self.stores.len()];
            }
            let shared_ref = &shared;
            ctx.exec.map_mut2(
                &mut self.stores,
                &mut self.tern_payloads,
                |_, store, buf| {
                    fuse::take_compact(store, shared_ref, buf);
                },
            );
            let blobs: Vec<TernBlob> = {
                let payloads: &[Vec<f32>] = &self.tern_payloads;
                ctx.exec.map_mut(ctx.node_rngs, |node, rng| {
                    TernBlob::encode(&payloads[node], rng)
                })
            };
            let rep_mask =
                ctx.topo
                    .spread_bytes(ctx.net, shared.wire_bytes(), mask_refs.len(), ctx.arena);
            let rep_blob =
                ctx.topo
                    .spread_bytes(ctx.net, blobs[0].wire_bytes(), n, ctx.arena);
            // Decode + sum in node order, then the sparse update on the
            // shared support with the 1/N scaling fused in.
            let mut summed = vec![0.0f32; shared.count()];
            for b in &blobs {
                b.add_decoded_into(&mut summed);
            }
            ctx.opt
                .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
            WireOutcome {
                wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                    / n as u64,
                payload_bytes: blobs[0].wire_bytes(),
                density: shared.density(),
                support_nnz: shared.count() as u64,
                wire_seconds: ctx.net.clock() - t0,
            }
        } else if let Some(width) = self.spec.quant {
            // `+q:<bits>` (bf16/f16/q8/q4): the `+tern` shape with
            // [`QBlob`] payloads — fused take + compact, parallel
            // per-node encode off each node's own RNG stream, mask +
            // whole-blob spreads, then decode-sum in node order at full
            // precision.
            let mask_refs: Vec<&BitMask> =
                self.mask_slots[..broadcasters.len()].iter().collect();
            let mut shared = BitMask::zeros(total);
            for m in &mask_refs {
                shared.or_assign(m);
            }
            if self.tern_payloads.len() != self.stores.len() {
                self.tern_payloads = vec![Vec::new(); self.stores.len()];
            }
            let shared_ref = &shared;
            ctx.exec.map_mut2(
                &mut self.stores,
                &mut self.tern_payloads,
                |_, store, buf| {
                    fuse::take_compact(store, shared_ref, buf);
                },
            );
            let blobs: Vec<QBlob> = {
                let payloads: &[Vec<f32>] = &self.tern_payloads;
                ctx.exec.map_mut(ctx.node_rngs, |node, rng| {
                    QBlob::encode(&payloads[node], width, rng)
                })
            };
            let rep_mask =
                ctx.topo
                    .spread_bytes(ctx.net, shared.wire_bytes(), mask_refs.len(), ctx.arena);
            let rep_blob =
                ctx.topo
                    .spread_bytes(ctx.net, blobs[0].wire_bytes(), n, ctx.arena);
            let mut summed = vec![0.0f32; shared.count()];
            for b in &blobs {
                b.add_decoded_into(&mut summed);
            }
            ctx.opt
                .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
            WireOutcome {
                wire_bytes_per_node: (rep_mask.total_bytes() + rep_blob.total_bytes())
                    / n as u64,
                payload_bytes: blobs[0].wire_bytes(),
                density: shared.density(),
                support_nnz: shared.count() as u64,
                wire_seconds: ctx.net.clock() - t0,
            }
        } else {
            // Shared-mask ring all-reduce (Alg. 1 lines 7–12).
            let mask_refs: Vec<&BitMask> =
                self.mask_slots[..broadcasters.len()].iter().collect();
            let values: Vec<&[f32]> = self.stores.iter().map(|s| s.pending()).collect();
            let (shared, summed, rep) =
                ctx.topo
                    .masked(ctx.net, &mask_refs, &values, ctx.exec, ctx.arena);
            // Fused residual take (momentum factor masking): zero
            // residual + velocity on the shared support in one sweep
            // per node.
            let shared_ref = &shared;
            ctx.exec.map_mut(&mut self.stores, |_, store| {
                store.clear_masked(shared_ref);
            });
            // Sparse SGD update on the shared support (Alg. 1 line 13).
            ctx.opt
                .step_sparse_mask(ctx.params, &shared, &summed, inv_n, ctx.lr);
            let nnz = shared.count();
            WireOutcome {
                wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                payload_bytes: wire_bytes(WireFormat::cheapest(total, nnz), total, nnz),
                density: shared.density(),
                support_nnz: nnz as u64,
                wire_seconds: ctx.net.clock() - t0,
            }
        };
        Ok(outcome)
    }

    fn pending(&self, node: usize) -> Option<&[f32]> {
        self.stores.get(node).map(|s| s.pending())
    }

    fn prev_stats(&self) -> &[LayerStats] {
        &self.prev_stats
    }

    fn remove_node(
        &mut self,
        node: usize,
        mode: RecoveryMode,
        nodes_after: usize,
        states_after: usize,
    ) {
        let total = self.stores[0].len();
        let momentum = self.stores[0].momentum();
        let layers = self.prev_stats.len();
        elastic_remove(&mut self.stores, node, mode, nodes_after);
        resize_stores(&mut self.stores, states_after, total, momentum);
        resize_scratch(&mut self.scratch, states_after, total, layers);
    }

    fn add_node(&mut self, epoch: usize, _nodes_after: usize, states_after: usize) {
        let total = self.stores[0].len();
        let momentum = self.stores[0].momentum();
        let layers = self.prev_stats.len();
        resize_stores(&mut self.stores, states_after, total, momentum);
        resize_scratch(&mut self.scratch, states_after, total, layers);
        // Warm-up re-entry: the threshold ramp restarts at the join
        // epoch so the newcomer's empty store does not destabilize
        // selection (its state is fresh — no stale residuals return).
        if self.warmup.epochs > 0 {
            self.epoch_base = epoch;
        }
    }

    fn export_node(&self, node: usize) -> Option<ResidualStore> {
        self.stores.get(node).cloned()
    }

    fn install_node(&mut self, node: usize, store: ResidualStore) {
        assert_eq!(store.len(), self.stores[node].len());
        self.stores[node] = store;
    }
}

// ---- per-node supports (DGC family) ------------------------------------

/// `dgc:*`: per-node support selection (magnitude top-k or Eq. 4
/// thresholded importance) over the sparse (densifying) transport.
struct PerNodeCompressor {
    spec: MethodSpec,
    select: DgcSelect,
    base_density: f64,
    warmup_epochs: usize,
    /// Epoch the warm-up schedule (re)started at — 0 until a mid-epoch
    /// join re-enters warm-up (DESIGN.md §15).
    epoch_base: usize,
    /// Top-k state (empty for the thresholded variant).
    dgcs: Vec<Dgc>,
    /// Thresholded-variant state (empty for top-k).
    stores: Vec<ResidualStore>,
    policy: ThresholdPolicy,
    warmup: Warmup,
    prev_stats: Vec<LayerStats>,
    thrs_buf: Vec<f32>,
    scratch: Vec<NodeScratch>,
}

impl PerNodeCompressor {
    fn new(spec: MethodSpec, select: DgcSelect, cfg: &StageCfg, layout: &ParamLayout) -> Self {
        let total = layout.total_params();
        let (warmup_epochs, warmup) = cfg.effective_warmup(&spec);
        let momentum = cfg.store_momentum(&spec);
        let (dgcs, stores, scratch, prev_stats) = match select {
            DgcSelect::TopK => (
                (0..cfg.state_nodes)
                    .map(|_| Dgc::new(total, cfg.dgc_density, momentum))
                    .collect(),
                Vec::new(),
                Vec::new(),
                Vec::new(),
            ),
            DgcSelect::Layerwise => (
                Vec::new(),
                (0..cfg.state_nodes)
                    .map(|_| ResidualStore::new(total, momentum))
                    .collect(),
                node_scratch(cfg.state_nodes, total, layout.n_layers()),
                vec![LayerStats::default(); layout.n_layers()],
            ),
        };
        PerNodeCompressor {
            spec,
            select,
            base_density: cfg.dgc_density,
            warmup_epochs,
            epoch_base: 0,
            dgcs,
            stores,
            policy: ThresholdPolicy::Layerwise(ThresholdCfg {
                alpha: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                ..Default::default()
            }),
            warmup,
            prev_stats,
            thrs_buf: Vec::with_capacity(layout.n_layers()),
            scratch,
        }
    }

    /// Thresholded per-node selection: one fused sweep per node
    /// (accumulate + score + hard-threshold select + stats), then the
    /// node-order stats merge and momentum factor masking on each
    /// node's *own* support. Shared by both engine paths.
    fn thresholded_select(
        &mut self,
        epoch: usize,
        layout: &ParamLayout,
        weights: &[f32],
        grads: &[Vec<f32>],
        exec: &Executor,
    ) {
        // Epoch counts from the last warm-up (re)entry (DESIGN.md §15).
        let epoch = epoch.saturating_sub(self.epoch_base);
        let wmult = self.warmup.multiplier(epoch);
        self.policy
            .layer_thresholds_into(layout, &self.prev_stats, epoch, wmult, &mut self.thrs_buf);
        {
            let thrs: &[f32] = &self.thrs_buf;
            exec.map_mut2(&mut self.stores, &mut self.scratch, |node, store, scr| {
                fuse::score_select_compact(
                    layout,
                    thrs,
                    weights,
                    &grads[node],
                    EPS,
                    false, // per-node selection is a hard threshold
                    &mut scr.rng,
                    store,
                    &mut scr.mask,
                    &mut scr.stats,
                );
            });
        }
        for s in self.prev_stats.iter_mut() {
            *s = LayerStats::default();
        }
        for scr in &self.scratch {
            for (li, st) in scr.stats.iter().enumerate() {
                self.prev_stats[li].merge(st);
            }
        }
    }
}

impl Compressor for PerNodeCompressor {
    fn spec(&self) -> MethodSpec {
        self.spec
    }

    fn grads_needed(&self, materialized: usize) -> usize {
        materialized
    }

    fn sim_step(&mut self, ctx: &mut SimCtx<'_>) -> WireOutcome {
        let t0 = ctx.net.clock();
        let total = ctx.layout.total_params();
        match self.select {
            DgcSelect::TopK => {
                let density = Dgc::density_at_epoch(
                    self.base_density,
                    ctx.epoch.saturating_sub(self.epoch_base),
                    self.warmup_epochs,
                );
                let k = ((total as f64) * density).ceil() as usize;
                let sim_nodes = self.dgcs.len();
                // Real top-k supports for materialized nodes; the
                // exchangeable stand-ins fill in beyond the cap. Both
                // halves are per-node-independent, so they fan out.
                let grads = ctx.grads;
                let mut supports: Vec<BitMask> =
                    ctx.exec.map_mut(&mut self.dgcs, |node, dgc| {
                        dgc.density = density;
                        let sv = dgc.step(&grads[node]);
                        let mut m = BitMask::zeros(total);
                        for &i in &sv.idx {
                            m.set(i as usize);
                        }
                        m
                    });
                supports.extend(exchangeable_supports(
                    ctx.exec,
                    &mut ctx.rngs[sim_nodes..],
                    k,
                    total,
                ));
                // Wire path: every support allgathers over real
                // sockets; the decoded masks drive the densification
                // measurement below.
                let supports = match ctx.wire.as_deref_mut() {
                    Some(w) => w
                        .allgather_supports(&supports)
                        .expect("wire support allgather failed"),
                    None => supports,
                };
                let rep =
                    ctx.topo
                        .sparse_support(ctx.net, &supports, ctx.exec, ctx.arena);
                // Paper-metric payload: each node's own encoded top-k.
                let payload = wire_bytes(WireFormat::cheapest(total, k), total, k);
                WireOutcome {
                    wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                    payload_bytes: payload,
                    density: rep.density_per_hop.last().copied().unwrap_or(density),
                    support_nnz: k as u64,
                    wire_seconds: ctx.net.clock() - t0,
                }
            }
            DgcSelect::Layerwise => {
                let sim_nodes = self.stores.len();
                self.thresholded_select(
                    ctx.epoch,
                    ctx.layout,
                    ctx.weights,
                    ctx.grads,
                    ctx.exec,
                );
                // Momentum factor masking on each node's own support.
                ctx.exec
                    .map_mut2(&mut self.stores, &mut self.scratch, |_, store, scr| {
                        store.clear_masked(&scr.mask);
                    });
                // Materialized supports travel as-is; exchangeable
                // k-subsets (k = mean materialized nnz) stand in for
                // the capped nodes, as in the top-k path.
                let counts: Vec<usize> =
                    self.scratch.iter().map(|s| s.mask.count()).collect();
                let k = counts.iter().sum::<usize>() / sim_nodes.max(1);
                let mut supports: Vec<BitMask> =
                    self.scratch.iter().map(|s| s.mask.clone()).collect();
                supports.extend(exchangeable_supports(
                    ctx.exec,
                    &mut ctx.rngs[sim_nodes..],
                    k,
                    total,
                ));
                let supports = match ctx.wire.as_deref_mut() {
                    Some(w) => w
                        .allgather_supports(&supports)
                        .expect("wire support allgather failed"),
                    None => supports,
                };
                let rep =
                    ctx.topo
                        .sparse_support(ctx.net, &supports, ctx.exec, ctx.arena);
                let own = counts.first().copied().unwrap_or(0);
                let payload = wire_bytes(WireFormat::cheapest(total, own), total, own);
                WireOutcome {
                    wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
                    payload_bytes: payload,
                    density: rep
                        .density_per_hop
                        .last()
                        .copied()
                        .unwrap_or(own as f64 / total.max(1) as f64),
                    support_nnz: own as u64,
                    wire_seconds: ctx.net.clock() - t0,
                }
            }
        }
    }

    fn train_reduce(&mut self, ctx: &mut TrainCtx<'_>) -> anyhow::Result<WireOutcome> {
        let t0 = ctx.net.clock();
        let n = ctx.nodes;
        let total = ctx.layout.total_params();
        let sparses: Vec<SparseVec> = match self.select {
            DgcSelect::TopK => {
                let density = Dgc::density_at_epoch(
                    self.base_density,
                    ctx.epoch.saturating_sub(self.epoch_base),
                    self.warmup_epochs,
                );
                let grads: &[Vec<f32>] = ctx.grads;
                ctx.exec.map_mut(&mut self.dgcs, |node, dgc| {
                    dgc.density = density;
                    dgc.step(&grads[node])
                })
            }
            DgcSelect::Layerwise => {
                {
                    let weights: &[f32] = ctx.params;
                    let grads: &[Vec<f32>] = ctx.grads;
                    self.thresholded_select(ctx.epoch, ctx.layout, weights, grads, ctx.exec);
                }
                let sparses: Vec<SparseVec> = self
                    .stores
                    .iter()
                    .zip(&self.scratch)
                    .map(|(store, scr)| SparseVec::from_mask(store.pending(), &scr.mask))
                    .collect();
                ctx.exec
                    .map_mut2(&mut self.stores, &mut self.scratch, |_, store, scr| {
                        store.clear_masked(&scr.mask);
                    });
                sparses
            }
        };
        let (sum, rep) = ctx.topo.sparse(ctx.net, &sparses, ctx.exec, ctx.arena);
        let inv_n = 1.0 / n as f32;
        for (i, &v) in sum.iter().enumerate() {
            if v != 0.0 {
                ctx.params[i] -= ctx.lr * v * inv_n;
            }
        }
        let k = sparses[0].nnz();
        Ok(WireOutcome {
            wire_bytes_per_node: rep.mean_bytes_per_node() as u64,
            payload_bytes: wire_bytes(WireFormat::cheapest(total, k), total, k),
            density: rep
                .density_per_hop
                .last()
                .copied()
                .unwrap_or(k as f64 / total.max(1) as f64),
            support_nnz: k as u64,
            wire_seconds: ctx.net.clock() - t0,
        })
    }

    fn pending(&self, node: usize) -> Option<&[f32]> {
        match self.select {
            DgcSelect::TopK => self.dgcs.get(node).map(|d| d.store().pending()),
            DgcSelect::Layerwise => self.stores.get(node).map(|s| s.pending()),
        }
    }

    fn prev_stats(&self) -> &[LayerStats] {
        &self.prev_stats
    }

    fn remove_node(
        &mut self,
        node: usize,
        mode: RecoveryMode,
        nodes_after: usize,
        states_after: usize,
    ) {
        match self.select {
            DgcSelect::TopK => {
                let total = self.dgcs[0].store().len();
                let momentum = self.dgcs[0].store().momentum();
                if node < self.dgcs.len() {
                    let departing = self.dgcs.remove(node);
                    if mode == RecoveryMode::Handoff && !self.dgcs.is_empty() {
                        let len = self.dgcs.len();
                        self.dgcs[node % len]
                            .store_mut()
                            .merge_from(departing.store());
                    }
                }
                if mode == RecoveryMode::DropRescale {
                    let factor = (nodes_after + 1) as f32 / nodes_after as f32;
                    for d in self.dgcs.iter_mut() {
                        d.store_mut().rescale(factor);
                    }
                }
                while self.dgcs.len() < states_after {
                    self.dgcs.push(Dgc::new(total, self.base_density, momentum));
                }
                self.dgcs.truncate(states_after);
            }
            DgcSelect::Layerwise => {
                let total = self.stores[0].len();
                let momentum = self.stores[0].momentum();
                let layers = self.prev_stats.len();
                elastic_remove(&mut self.stores, node, mode, nodes_after);
                resize_stores(&mut self.stores, states_after, total, momentum);
                resize_scratch(&mut self.scratch, states_after, total, layers);
            }
        }
    }

    fn add_node(&mut self, epoch: usize, _nodes_after: usize, states_after: usize) {
        match self.select {
            DgcSelect::TopK => {
                let total = self.dgcs[0].store().len();
                let momentum = self.dgcs[0].store().momentum();
                while self.dgcs.len() < states_after {
                    self.dgcs.push(Dgc::new(total, self.base_density, momentum));
                }
                self.dgcs.truncate(states_after);
            }
            DgcSelect::Layerwise => {
                let total = self.stores[0].len();
                let momentum = self.stores[0].momentum();
                let layers = self.prev_stats.len();
                resize_stores(&mut self.stores, states_after, total, momentum);
                resize_scratch(&mut self.scratch, states_after, total, layers);
            }
        }
        // Warm-up re-entry at the join epoch (DESIGN.md §15): the DGC
        // density ramp and the threshold ramp both restart, and the
        // newcomer's store starts zeroed — no stale residuals return.
        if self.warmup_epochs > 0 {
            self.epoch_base = epoch;
        }
    }

    fn export_node(&self, node: usize) -> Option<ResidualStore> {
        match self.select {
            DgcSelect::TopK => self.dgcs.get(node).map(|d| d.store().clone()),
            DgcSelect::Layerwise => self.stores.get(node).cloned(),
        }
    }

    fn install_node(&mut self, node: usize, store: ResidualStore) {
        match self.select {
            DgcSelect::TopK => {
                assert_eq!(store.len(), self.dgcs[node].store().len());
                *self.dgcs[node].store_mut() = store;
            }
            DgcSelect::Layerwise => {
                assert_eq!(store.len(), self.stores[node].len());
                self.stores[node] = store;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Method;
    use crate::model::LayerKind;

    fn layout() -> ParamLayout {
        ParamLayout::new(
            "pipe_t",
            vec![
                ("conv".into(), vec![8, 4, 3], LayerKind::Conv),
                ("fc".into(), vec![16, 4], LayerKind::Fc),
            ],
        )
    }

    fn cfg() -> StageCfg {
        StageCfg {
            nodes: 4,
            state_nodes: 4,
            threshold: 0.05,
            beta: 0.002,
            c: 1.0,
            mask_nodes: 2,
            random_select: true,
            momentum: 0.9,
            dgc_density: 0.01,
            warmup_epochs: 0,
        }
    }

    #[test]
    fn every_registry_spec_builds() {
        for e in crate::compress::spec::REGISTRY {
            let spec = MethodSpec::parse(e.spec).unwrap();
            let c = build(spec, &cfg(), &layout());
            assert_eq!(c.spec(), spec, "{}", e.spec);
        }
    }

    #[test]
    fn grads_needed_matches_transport_class() {
        let l = layout();
        assert_eq!(build(Method::Baseline.spec(), &cfg(), &l).grads_needed(4), 0);
        assert_eq!(build(Method::TernGrad.spec(), &cfg(), &l).grads_needed(4), 1);
        assert_eq!(build(Method::IwpFixed.spec(), &cfg(), &l).grads_needed(4), 4);
        assert_eq!(build(Method::Dgc.spec(), &cfg(), &l).grads_needed(4), 4);
    }

    /// A store with known integral pending values (`seed + i`) —
    /// integral f32s add exactly, so the conservation asserts below
    /// hold bit-for-bit, not just to tolerance.
    fn filled_store(total: usize, seed: f32) -> ResidualStore {
        let mut s = ResidualStore::new(total, 0.0);
        let g: Vec<f32> = (0..total).map(|i| seed + i as f32).collect();
        s.accumulate(&g);
        s
    }

    #[test]
    fn remove_node_handoff_merges_into_ring_successor() {
        let l = layout();
        let total = l.total_params();
        let mut c = build(Method::IwpFixed.spec(), &cfg(), &l);
        for node in 0..4 {
            c.install_node(node, filled_store(total, 1.0 + node as f32));
        }
        let before: f64 = (0..4)
            .map(|n| c.export_node(n).unwrap().residual_sum())
            .sum();
        let expect: Vec<f32> = {
            let a = c.export_node(1).unwrap();
            let b = c.export_node(2).unwrap();
            a.pending().iter().zip(b.pending()).map(|(x, y)| x + y).collect()
        };
        c.remove_node(1, RecoveryMode::Handoff, 3, 3);
        // Node 1's mass landed on its ring successor — post-removal
        // slot 1 % 3 = 1, the store that was node 2.
        assert_eq!(c.pending(1).unwrap(), &expect[..]);
        let after: f64 = (0..3)
            .map(|n| c.export_node(n).unwrap().residual_sum())
            .sum();
        assert_eq!(before, after, "handoff must conserve total pending mass");
        assert!(c.export_node(3).is_none(), "state shrank to 3 slots");
    }

    #[test]
    fn remove_node_rescale_scales_survivors_exactly() {
        let l = layout();
        let total = l.total_params();
        let mut c = build(Method::IwpFixed.spec(), &cfg(), &l);
        for node in 0..4 {
            c.install_node(node, filled_store(total, 1.0 + node as f32));
        }
        let base: Vec<Vec<f32>> = (0..4)
            .map(|n| c.export_node(n).unwrap().pending().to_vec())
            .collect();
        // nodes_after = 4 -> factor 5/4 = 1.25, exact on integral f32s.
        c.remove_node(0, RecoveryMode::DropRescale, 4, 4);
        for slot in 0..3 {
            let got = c.pending(slot).unwrap();
            for (g, b) in got.iter().zip(&base[slot + 1]) {
                assert_eq!(g.to_bits(), (b * 1.25).to_bits());
            }
        }
        // The slot backfilled to the post-event state count is fresh.
        assert_eq!(c.export_node(3).unwrap().residual_sum(), 0.0);
    }

    #[test]
    fn exchangeable_crash_beyond_cap_leaves_handoff_state_untouched() {
        // A crash of a node beyond the materialized cap has no store to
        // migrate: handoff must leave every materialized store
        // bit-identical (rescale would still apply — the expectation
        // argument, DESIGN.md §15).
        let l = layout();
        let total = l.total_params();
        let mut c = build(Method::IwpFixed.spec(), &cfg(), &l);
        for node in 0..4 {
            c.install_node(node, filled_store(total, 1.0 + node as f32));
        }
        let base: Vec<Vec<f32>> = (0..4)
            .map(|n| c.export_node(n).unwrap().pending().to_vec())
            .collect();
        c.remove_node(6, RecoveryMode::Handoff, 7, 4);
        for (slot, b) in base.iter().enumerate() {
            assert_eq!(c.pending(slot).unwrap(), &b[..], "slot {slot}");
        }
    }

    #[test]
    fn add_node_zeroes_new_store_and_rebases_warmup() {
        let l = layout();
        let mut sc = cfg();
        sc.state_nodes = 3;
        let spec = MethodSpec::parse("iwp:fixed+warmup:4").unwrap();
        let mut c = SharedMaskCompressor::new(spec, IwpPolicy::Fixed, &sc, &l);
        c.stores[0].accumulate(&vec![1.0; l.total_params()]);
        c.add_node(5, 4, 4);
        assert_eq!(c.stores.len(), 4);
        assert_eq!(
            c.stores[3].residual_sum(),
            0.0,
            "a join never resurrects stale residuals"
        );
        assert_eq!(c.epoch_base, 5, "warm-up re-enters at the join epoch");
        // Without a warm-up schedule there is nothing to re-enter.
        let spec = MethodSpec::parse("iwp:fixed").unwrap();
        let mut c2 = SharedMaskCompressor::new(spec, IwpPolicy::Fixed, &cfg(), &l);
        c2.add_node(5, 5, 4);
        assert_eq!(c2.epoch_base, 0);
    }

    #[test]
    fn dgc_topk_handoff_merges_into_successor_store() {
        let l = layout();
        let total = l.total_params();
        let mut c = build(Method::Dgc.spec(), &cfg(), &l);
        for node in 0..4 {
            c.install_node(node, filled_store(total, 1.0 + node as f32));
        }
        let expect: Vec<f32> = {
            let a = c.export_node(2).unwrap();
            let b = c.export_node(3).unwrap();
            a.pending().iter().zip(b.pending()).map(|(x, y)| x + y).collect()
        };
        // Remove slot 2: survivors [0, 1, 3]; successor 2 % 3 = 2, the
        // store that was node 3.
        c.remove_node(2, RecoveryMode::Handoff, 3, 3);
        assert_eq!(c.pending(2).unwrap(), &expect[..]);
    }

    #[test]
    fn export_install_roundtrip_is_bit_exact() {
        let l = layout();
        let total = l.total_params();
        for spec in ["iwp:fixed", "dgc", "dgc:layerwise"] {
            let mut c = build(MethodSpec::parse(spec).unwrap(), &cfg(), &l);
            let store = filled_store(total, 7.0);
            c.install_node(1, store.clone());
            let out = c.export_node(1).unwrap();
            let bits = |s: &ResidualStore| -> Vec<u32> {
                s.pending().iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&out), bits(&store), "{spec}");
        }
        // Stateless pipelines have nothing to migrate.
        assert!(build(Method::Baseline.spec(), &cfg(), &l).export_node(0).is_none());
        assert!(build(Method::TernGrad.spec(), &cfg(), &l).export_node(0).is_none());
    }

    #[test]
    fn stage_overrides_flow_into_state() {
        let l = layout();
        // +nomcorr zeroes the residual-store momentum: after one
        // accumulate of g the pending value is g (vs g with momentum
        // too on step one — observable on step two).
        let c = build(
            MethodSpec::parse("iwp:fixed+nomcorr").unwrap(),
            &cfg(),
            &l,
        );
        assert!(c.pending(0).is_some());
        // Dense/ternary pipelines keep no residual state.
        assert!(build(Method::Baseline.spec(), &cfg(), &l).pending(0).is_none());
        assert!(build(Method::TernGrad.spec(), &cfg(), &l).pending(0).is_none());
        // Scoring pipelines expose trailing stats rows, one per layer
        // (after the first step; initialized to defaults).
        let c = build(MethodSpec::parse("dgc:layerwise").unwrap(), &cfg(), &l);
        assert_eq!(c.prev_stats().len(), l.n_layers());
        let c = build(Method::Dgc.spec(), &cfg(), &l);
        assert!(c.prev_stats().is_empty());
    }
}
