//! Method-spec grammar and registry (DESIGN.md §12).
//!
//! The paper's IWP method is one point in a family — scoring × threshold
//! policy × selection × residual store × optional quantization. This
//! module names every point in that family with a string spec, mirroring
//! the topology grammar (`net::topo::TopoKind::parse`):
//!
//! ```text
//! <head>[+<stage>]*
//!
//! head  := dense | terngrad
//!        | iwp:fixed | iwp:layerwise | iwp:vargate[:<gate>[:<boost>]]
//!        | dgc:topk  | dgc:layerwise
//! stage := warmup:<epochs> | mcorr | nomcorr | sel | nosel | tern | q:<bits>
//! bits  := 16b | 16 | 8 | 4 | 2
//! ```
//!
//! `+q:<bits>` selects the wire precision of the compacted shared-mask
//! payload (compress/quant.rs, DESIGN.md §17); `+tern` is the pinned
//! alias of its 2-bit special case, so `iwp:fixed+q:2` canonicalizes to
//! `iwp:fixed+tern`.
//!
//! Every legacy `Method` enum value maps to a canonical spec
//! ([`super::Method::spec`]) and runs bit-identically to the
//! pre-refactor engine (`rust/tests/compressor_equivalence.rs`). The
//! CLI flag (`--method`), the config-file key (`method = …`), and the
//! `RINGIWP_METHOD` environment default all route through the single
//! validated entry point [`MethodSpec::parse`].

use super::Method;
use crate::compress::quant::QuantWidth;

/// Default var/mean gate of `iwp:vargate` (trailing dispersion above
/// this marks a layer as noisy — Tsuzuku et al., 1802.06058 adapted to
/// trailing layer stats).
pub const VARGATE_GATE: f32 = 1.0;
/// Default threshold boost `iwp:vargate` applies to noisy layers.
pub const VARGATE_BOOST: f32 = 4.0;

/// Threshold policy of the shared-mask (IWP) family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IwpPolicy {
    /// One global threshold (Table I "Fix Threshold").
    Fixed,
    /// The Eq. 4 per-layer controller.
    Layerwise,
    /// Variance-gated step rule: layers whose trailing var/mean exceeds
    /// `gate` compress `boost`× harder (`iwp:vargate[:g[:b]]`).
    VarGate {
        /// Trailing var/mean above which a layer counts as noisy.
        gate: f32,
        /// Threshold multiplier applied to noisy layers.
        boost: f32,
    },
}

/// Per-node support-selection rule of the DGC family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DgcSelect {
    /// Magnitude top-k at the configured density (the legacy baseline).
    TopK,
    /// Importance over Eq. 4 layerwise thresholds — IWP scoring on DGC
    /// transport (per-node masks, densifies on rings).
    Layerwise,
}

/// Head of a method spec: scoring × selection × transport class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpecHead {
    /// Dense synchronous SGD — full gradients on the wire.
    Dense,
    /// TernGrad ternary quantization; blobs spread whole.
    Terngrad,
    /// Shared-mask importance pruning (Algorithm 1 transport).
    Iwp(IwpPolicy),
    /// Per-node-support selection (DGC transport).
    Dgc(DgcSelect),
}

/// A fully parsed, validated compression-pipeline spec: one head plus
/// stage overrides. Built only through [`MethodSpec::parse`] or
/// [`super::Method::spec`], so an in-hand value is always valid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MethodSpec {
    /// Scoring/selection/transport family.
    pub head: SpecHead,
    /// `+warmup:<epochs>` — overrides the config's warm-up epochs.
    pub warmup: Option<usize>,
    /// `+mcorr` / `+nomcorr` — momentum-corrected residual store.
    /// `None` means the default (on for sparsifying heads); parse
    /// normalizes a redundant `+mcorr` back to `None`.
    pub mcorr: Option<bool>,
    /// `+sel` / `+nosel` — randomized-selection override (`None` defers
    /// to the config's `random_select`).
    pub random_select: Option<bool>,
    /// `+q:<bits>` / `+tern` — wire precision of the compacted
    /// shared-mask payload; the quantized blobs spread whole (not closed
    /// under addition). `+tern` is the alias of `+q:2`.
    pub quant: Option<QuantWidth>,
}

/// One row of the spec registry (`ringiwp methods`).
#[derive(Debug, Clone, Copy)]
pub struct SpecEntry {
    /// Canonical spec string (re-parseable).
    pub spec: &'static str,
    /// Legacy `Method` alias, if this head replaces one.
    pub legacy: Option<&'static str>,
    /// One-line description.
    pub desc: &'static str,
}

/// Registered heads, in `ringiwp methods` display order.
pub const REGISTRY: [SpecEntry; 9] = [
    SpecEntry {
        spec: "dense",
        legacy: Some("baseline"),
        desc: "dense synchronous SGD; full gradients on the wire",
    },
    SpecEntry {
        spec: "terngrad",
        legacy: Some("terngrad"),
        desc: "ternary quantization; blobs spread whole (Wen et al. 2017)",
    },
    SpecEntry {
        spec: "iwp:fixed",
        legacy: Some("iwp-fixed"),
        desc: "importance pruning, one global threshold (Table I \"Fix Threshold\")",
    },
    SpecEntry {
        spec: "iwp:layerwise",
        legacy: Some("iwp-layerwise"),
        desc: "importance pruning, Eq. 4 per-layer thresholds",
    },
    SpecEntry {
        spec: "iwp:vargate",
        legacy: None,
        desc: "variance-gated IWP: layers with trailing var/mean > gate compress boost x \
               harder (default gate 1, boost 4; Tsuzuku et al. 2018)",
    },
    SpecEntry {
        spec: "iwp:layerwise+q:8",
        legacy: None,
        desc: "layerwise IWP with an 8-bit block-quantized payload (127 levels/sign, \
               unbiased stochastic rounding; DESIGN.md §17)",
    },
    SpecEntry {
        spec: "iwp:fixed+q:16b",
        legacy: None,
        desc: "fixed-threshold IWP with a bf16 payload (deterministic round-to-nearest; \
               halves masked values bytes)",
    },
    SpecEntry {
        spec: "dgc:topk",
        legacy: Some("dgc"),
        desc: "per-node magnitude top-k (Lin et al. 2017); densifies on rings",
    },
    SpecEntry {
        spec: "dgc:layerwise",
        legacy: None,
        desc: "per-node importance selection under Eq. 4 thresholds (IWP scoring x DGC \
               transport)",
    },
];

/// Stage grammar, in `ringiwp methods` display order.
pub const STAGES: [(&str, &str); 7] = [
    ("+warmup:<epochs>", "override warm-up epochs (threshold/density ramp; iwp/dgc heads)"),
    ("+mcorr", "momentum-corrected residual store (Eq. 3; the default for iwp/dgc heads)"),
    ("+nomcorr", "raw residual accumulation (momentum correction off; iwp/dgc heads)"),
    ("+sel", "randomized selection P = I/thr on (Sec. III-C; iwp heads)"),
    ("+nosel", "hard thresholding (randomized selection off; iwp heads)"),
    ("+tern", "ternary-quantize the compacted shared-mask payload; blobs spread whole (iwp heads)"),
    (
        "+q:<bits>",
        "wire precision of the compacted shared-mask payload: 16b (bf16) | 16 (f16) | \
         8 | 4 | 2 (block-quantized, unbiased stochastic rounding; +tern = +q:2; iwp heads)",
    ),
];

impl MethodSpec {
    /// A bare head with no stage overrides.
    pub fn bare(head: SpecHead) -> Self {
        MethodSpec {
            head,
            warmup: None,
            mcorr: None,
            random_select: None,
            quant: None,
        }
    }

    /// Parse a method spec — the single validated entry point behind
    /// the `--method` flag, the `method =` config key, and the
    /// `RINGIWP_METHOD` environment default. Accepts the legacy
    /// `Method` aliases (`baseline`, `iwp-fixed`, …) as head synonyms.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        let mut parts = s.split('+');
        let head_s = parts.next().unwrap_or("").trim();
        let head = parse_head(head_s)?;
        let mut spec = MethodSpec::bare(head);
        for stage in parts {
            let stage = stage.trim();
            match stage {
                "mcorr" => set_once(&mut spec.mcorr, true, "mcorr/nomcorr")?,
                "nomcorr" => set_once(&mut spec.mcorr, false, "mcorr/nomcorr")?,
                "sel" => set_once(&mut spec.random_select, true, "sel/nosel")?,
                "nosel" => set_once(&mut spec.random_select, false, "sel/nosel")?,
                "tern" => {
                    anyhow::ensure!(
                        spec.quant.is_none(),
                        "conflicting/duplicate quantization stages (`+tern`/`+q:<bits>`)"
                    );
                    spec.quant = Some(QuantWidth::Q2);
                }
                other => {
                    if let Some(e) = other.strip_prefix("warmup:") {
                        let epochs: usize = e.parse().map_err(|_| {
                            anyhow::anyhow!("+warmup:<epochs> expects an integer, got `{e}`")
                        })?;
                        anyhow::ensure!(
                            spec.warmup.is_none(),
                            "duplicate `+warmup` stage"
                        );
                        spec.warmup = Some(epochs);
                    } else if let Some(w) = other.strip_prefix("q:") {
                        anyhow::ensure!(
                            spec.quant.is_none(),
                            "conflicting/duplicate quantization stages (`+tern`/`+q:<bits>`)"
                        );
                        spec.quant = Some(QuantWidth::parse(w)?);
                    } else {
                        anyhow::bail!(
                            "unknown stage `+{other}` (warmup:<epochs> | mcorr | nomcorr | \
                             sel | nosel | tern | q:<bits>)"
                        );
                    }
                }
            }
        }
        spec.validate()?;
        // Momentum correction is the spec-level default for sparsifying
        // heads; after validation, normalize a redundant `+mcorr` so
        // specs compare equal.
        if spec.mcorr == Some(true) {
            spec.mcorr = None;
        }
        Ok(spec)
    }

    /// Reject stage/head combinations that have no meaning.
    pub fn validate(&self) -> anyhow::Result<()> {
        let sparsifying = matches!(self.head, SpecHead::Iwp(_) | SpecHead::Dgc(_));
        if !sparsifying {
            anyhow::ensure!(
                self.warmup.is_none() && self.mcorr.is_none(),
                "`+warmup`/`+mcorr`/`+nomcorr` only apply to iwp/dgc heads"
            );
        }
        let iwp = matches!(self.head, SpecHead::Iwp(_));
        anyhow::ensure!(
            self.random_select.is_none() || iwp,
            "`+sel`/`+nosel` (randomized selection, Sec. III-C) only applies to iwp heads"
        );
        // Payload quantization (`+tern`/`+q`) rides the shared-mask
        // transport: a single compacted payload per step, spread whole.
        // Every other head lacks that payload for a *head-specific*
        // reason, so the rejection says which one (the old message
        // explained only the dgc:topk case).
        if self.quant.is_some() && !iwp {
            let stage = match self.quant {
                Some(QuantWidth::Q2) => "`+tern`".to_string(),
                Some(w) => format!("`+q:{}`", w.token()),
                None => unreachable!(),
            };
            match self.head {
                SpecHead::Dense => anyhow::bail!(
                    "{stage} quantizes the compacted shared-mask payload; the dense head \
                     ships full gradients with no mask or compaction (use the `terngrad` \
                     head for full-gradient quantization)"
                ),
                SpecHead::Terngrad => anyhow::bail!(
                    "{stage} is redundant on the `terngrad` head, which already \
                     ternary-quantizes the full gradient before it reaches the wire"
                ),
                SpecHead::Dgc(DgcSelect::TopK) => anyhow::bail!(
                    "{stage} quantizes the compacted shared-mask payload; dgc:topk ships \
                     per-node magnitude top-k supports as sparse (index, value) pairs that \
                     densify on the ring — there is no shared compacted payload to quantize"
                ),
                SpecHead::Dgc(DgcSelect::Layerwise) => anyhow::bail!(
                    "{stage} quantizes the compacted shared-mask payload; dgc:layerwise \
                     scores by importance but still ships per-node supports on the \
                     densifying sparse transport, so it has no shared compacted payload \
                     either"
                ),
                SpecHead::Iwp(_) => unreachable!(),
            }
        }
        if let SpecHead::Iwp(IwpPolicy::VarGate { gate, boost }) = self.head {
            anyhow::ensure!(
                gate >= 0.0 && gate.is_finite(),
                "vargate gate must be finite and >= 0"
            );
            anyhow::ensure!(
                boost >= 1.0 && boost.is_finite(),
                "vargate boost must be finite and >= 1"
            );
        }
        Ok(())
    }

    /// Canonical spec string, re-parseable by [`MethodSpec::parse`]
    /// (`iwp:layerwise`, `dgc:topk`, `iwp:fixed+warmup:4+nosel+tern`).
    pub fn name(&self) -> String {
        let mut out = match self.head {
            SpecHead::Dense => "dense".to_string(),
            SpecHead::Terngrad => "terngrad".to_string(),
            SpecHead::Iwp(IwpPolicy::Fixed) => "iwp:fixed".to_string(),
            SpecHead::Iwp(IwpPolicy::Layerwise) => "iwp:layerwise".to_string(),
            SpecHead::Iwp(IwpPolicy::VarGate { gate, boost }) => {
                if gate == VARGATE_GATE && boost == VARGATE_BOOST {
                    "iwp:vargate".to_string()
                } else {
                    format!("iwp:vargate:{gate}:{boost}")
                }
            }
            SpecHead::Dgc(DgcSelect::TopK) => "dgc:topk".to_string(),
            SpecHead::Dgc(DgcSelect::Layerwise) => "dgc:layerwise".to_string(),
        };
        if let Some(e) = self.warmup {
            out.push_str(&format!("+warmup:{e}"));
        }
        if self.mcorr == Some(false) {
            out.push_str("+nomcorr");
        }
        match self.random_select {
            Some(true) => out.push_str("+sel"),
            Some(false) => out.push_str("+nosel"),
            None => {}
        }
        match self.quant {
            // `+tern` is the pinned alias of the 2-bit case: `+q:2`
            // canonicalizes to the historical spelling.
            Some(QuantWidth::Q2) => out.push_str("+tern"),
            Some(w) => {
                out.push_str("+q:");
                out.push_str(w.token());
            }
            None => {}
        }
        out
    }

    /// Human label: the paper's Table-I label for legacy specs, the
    /// canonical spec string for everything else.
    pub fn table_label(&self) -> String {
        match self.legacy() {
            Some(m) => m.table_label().to_string(),
            None => self.name(),
        }
    }

    /// The legacy `Method` this spec is the canonical replacement of —
    /// `None` for the new compositions and any stage-overridden spec.
    pub fn legacy(&self) -> Option<Method> {
        if self.warmup.is_some() || self.mcorr.is_some() || self.random_select.is_some()
            || self.quant.is_some()
        {
            return None;
        }
        match self.head {
            SpecHead::Dense => Some(Method::Baseline),
            SpecHead::Terngrad => Some(Method::TernGrad),
            SpecHead::Iwp(IwpPolicy::Fixed) => Some(Method::IwpFixed),
            SpecHead::Iwp(IwpPolicy::Layerwise) => Some(Method::IwpLayerwise),
            SpecHead::Dgc(DgcSelect::TopK) => Some(Method::Dgc),
            _ => None,
        }
    }

    /// Whether this spec's trainer path scores through the PJRT L1
    /// importance kernel (the shared-mask family does).
    pub fn needs_kernel(&self) -> bool {
        matches!(self.head, SpecHead::Iwp(_))
    }

    /// Whether the *global optimizer* carries the momentum (dense paths)
    /// rather than the per-node residual store (momentum correction,
    /// Eq. 3 — the sparsifying paths).
    pub fn optimizer_momentum(&self) -> bool {
        matches!(self.head, SpecHead::Dense | SpecHead::Terngrad)
    }

    /// Environment default: `RINGIWP_METHOD`, else `fallback` (mirrors
    /// `RINGIWP_TOPOLOGY`). A set-but-malformed value panics with the
    /// parse error rather than silently running the wrong method.
    pub fn from_env_or(fallback: MethodSpec) -> Self {
        match std::env::var("RINGIWP_METHOD") {
            Ok(s) => MethodSpec::parse(&s)
                .unwrap_or_else(|e| panic!("RINGIWP_METHOD={s}: {e}")),
            Err(_) => fallback,
        }
    }
}

impl std::fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

fn set_once(slot: &mut Option<bool>, value: bool, what: &str) -> anyhow::Result<()> {
    anyhow::ensure!(slot.is_none(), "conflicting/duplicate `{what}` stages");
    *slot = Some(value);
    Ok(())
}

fn parse_head(s: &str) -> anyhow::Result<SpecHead> {
    Ok(match s {
        "dense" | "baseline" => SpecHead::Dense,
        "terngrad" => SpecHead::Terngrad,
        "iwp:fixed" | "iwp-fixed" | "fixed" => SpecHead::Iwp(IwpPolicy::Fixed),
        "iwp:layerwise" | "iwp-layerwise" | "layerwise" => SpecHead::Iwp(IwpPolicy::Layerwise),
        "dgc:topk" | "dgc" | "topk" => SpecHead::Dgc(DgcSelect::TopK),
        "dgc:layerwise" => SpecHead::Dgc(DgcSelect::Layerwise),
        other => {
            if let Some(rest) = other.strip_prefix("iwp:vargate") {
                let (gate, boost) = match rest {
                    "" => (VARGATE_GATE, VARGATE_BOOST),
                    _ => {
                        let rest = rest.strip_prefix(':').ok_or_else(|| {
                            anyhow::anyhow!("unknown method head `{other}`")
                        })?;
                        match rest.split_once(':') {
                            Some((g, b)) => (parse_f32(g, "gate")?, parse_f32(b, "boost")?),
                            None => (parse_f32(rest, "gate")?, VARGATE_BOOST),
                        }
                    }
                };
                return Ok(SpecHead::Iwp(IwpPolicy::VarGate { gate, boost }));
            }
            anyhow::bail!(
                "unknown method `{other}` — heads: dense | terngrad | iwp:fixed | \
                 iwp:layerwise | iwp:vargate[:<gate>[:<boost>]] | dgc:topk | \
                 dgc:layerwise (run `ringiwp methods` for the registry)"
            )
        }
    })
}

fn parse_f32(s: &str, what: &str) -> anyhow::Result<f32> {
    s.parse::<f32>()
        .map_err(|_| anyhow::anyhow!("vargate {what} expects a number, got `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_entries_roundtrip_through_parse() {
        for e in REGISTRY {
            let spec = MethodSpec::parse(e.spec).unwrap();
            assert_eq!(spec.name(), e.spec, "registry spec must be canonical");
            assert_eq!(MethodSpec::parse(&spec.name()).unwrap(), spec);
            match e.legacy {
                Some(alias) => {
                    assert_eq!(MethodSpec::parse(alias).unwrap(), spec, "alias {alias}");
                    assert_eq!(spec.legacy(), Some(Method::parse(alias).unwrap()));
                }
                None => assert_eq!(spec.legacy(), None),
            }
        }
    }

    #[test]
    fn legacy_methods_map_to_pinned_canonical_specs() {
        let table = [
            (Method::Baseline, "dense"),
            (Method::TernGrad, "terngrad"),
            (Method::IwpFixed, "iwp:fixed"),
            (Method::IwpLayerwise, "iwp:layerwise"),
            (Method::Dgc, "dgc:topk"),
        ];
        for (m, canon) in table {
            assert_eq!(m.spec().name(), canon);
            assert_eq!(m.spec().legacy(), Some(m));
        }
    }

    #[test]
    fn stages_parse_and_canonicalize() {
        let s = MethodSpec::parse("iwp:layerwise+warmup:4+mcorr").unwrap();
        assert_eq!(s.warmup, Some(4));
        // Redundant +mcorr normalizes away; canonical name is minimal.
        assert_eq!(s.mcorr, None);
        assert_eq!(s.name(), "iwp:layerwise+warmup:4");
        let s = MethodSpec::parse("iwp:fixed+nosel+tern").unwrap();
        assert_eq!(s.random_select, Some(false));
        assert_eq!(s.quant, Some(QuantWidth::Q2));
        assert_eq!(s.name(), "iwp:fixed+nosel+tern");
        assert_eq!(MethodSpec::parse(&s.name()).unwrap(), s);
        let s = MethodSpec::parse("dgc:layerwise+nomcorr+warmup:2").unwrap();
        assert_eq!(s.mcorr, Some(false));
        assert_eq!(s.name(), "dgc:layerwise+warmup:2+nomcorr");
    }

    #[test]
    fn q_stage_parses_every_width_and_q2_canonicalizes_as_tern() {
        for (tok, width) in [
            ("16b", QuantWidth::Bf16),
            ("16", QuantWidth::F16),
            ("8", QuantWidth::Q8),
            ("4", QuantWidth::Q4),
        ] {
            let spec_s = format!("iwp:layerwise+q:{tok}");
            let s = MethodSpec::parse(&spec_s).unwrap();
            assert_eq!(s.quant, Some(width));
            assert_eq!(s.name(), spec_s, "non-2-bit widths spell as +q:<bits>");
            assert_eq!(MethodSpec::parse(&s.name()).unwrap(), s);
            assert_eq!(s.legacy(), None);
        }
        // `+tern` is the pinned alias of `+q:2`: both parse to the same
        // spec and the canonical spelling is the historical one.
        let via_q = MethodSpec::parse("iwp:fixed+q:2").unwrap();
        let via_tern = MethodSpec::parse("iwp:fixed+tern").unwrap();
        assert_eq!(via_q, via_tern);
        assert_eq!(via_q.quant, Some(QuantWidth::Q2));
        assert_eq!(via_q.name(), "iwp:fixed+tern");
        // Stage ordering is normalized through name().
        let s = MethodSpec::parse("iwp:vargate+q:4+nosel+warmup:3").unwrap();
        assert_eq!(s.name(), "iwp:vargate+warmup:3+nosel+q:4");
    }

    #[test]
    fn quant_rejections_are_per_head_accurate() {
        // Satellite pin (ISSUE 10): each non-iwp head rejects `+q`/`+tern`
        // with a message explaining *that head's* transport, not just the
        // dgc:topk story.
        for (bad, needle) in [
            ("dense+tern", "full gradients with no mask"),
            ("dense+q:8", "full gradients with no mask"),
            ("terngrad+tern", "already"),
            ("terngrad+q:4", "already"),
            ("dgc:topk+tern", "magnitude top-k"),
            ("dgc:topk+q:8", "magnitude top-k"),
            ("dgc:layerwise+tern", "scores by importance"),
            ("dgc:layerwise+q:16b", "scores by importance"),
        ] {
            let err = MethodSpec::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains(needle),
                "`{bad}` error must mention `{needle}`, got: {err}"
            );
        }
        // And the stage spelling the user wrote is echoed back.
        let err = MethodSpec::parse("dense+q:8").unwrap_err().to_string();
        assert!(err.contains("`+q:8`"), "{err}");
        let err = MethodSpec::parse("dense+tern").unwrap_err().to_string();
        assert!(err.contains("`+tern`"), "{err}");
    }

    #[test]
    fn vargate_parameters_parse_and_roundtrip() {
        let s = MethodSpec::parse("iwp:vargate").unwrap();
        assert_eq!(
            s.head,
            SpecHead::Iwp(IwpPolicy::VarGate {
                gate: VARGATE_GATE,
                boost: VARGATE_BOOST
            })
        );
        let s = MethodSpec::parse("iwp:vargate:2.5").unwrap();
        assert_eq!(
            s.head,
            SpecHead::Iwp(IwpPolicy::VarGate {
                gate: 2.5,
                boost: VARGATE_BOOST
            })
        );
        let s = MethodSpec::parse("iwp:vargate:0.5:8").unwrap();
        assert_eq!(s.name(), "iwp:vargate:0.5:8");
        assert_eq!(MethodSpec::parse(&s.name()).unwrap(), s);
    }

    #[test]
    fn grammar_rejects() {
        for bad in [
            "nope",
            "iwp",
            "iwp:",
            "iwp:vargate:",
            "iwp:vargate:x",
            "iwp:vargate:1:0.5", // boost < 1
            "dgc:",
            "dgc:nope",
            "dense+warmup:2",     // warmup on a dense head
            "terngrad+mcorr",     // store stage on a quantization head
            "dgc:topk+sel",       // randomized selection is an iwp stage
            "dgc:topk+tern",      // quantization is an iwp stage
            "dgc:layerwise+q:8",  // … on every dgc head
            "iwp:fixed+warmup:x", // malformed epochs
            "iwp:fixed+warmup:1+warmup:2",
            "iwp:fixed+sel+nosel",
            "iwp:fixed+mcorr+nomcorr",
            "iwp:fixed+tern+tern",
            "iwp:fixed+q:3",      // not a registered width
            "iwp:fixed+q:",       // missing width
            "iwp:fixed+q:32",     // f32 is the unquantized default, not a stage
            "iwp:fixed+tern+q:8", // conflicting quantization stages
            "iwp:fixed+q:2+q:2",  // duplicate via the alias too
            "iwp:fixed+bogus",
        ] {
            assert!(MethodSpec::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn env_fallback_applies_when_unset() {
        // RINGIWP_METHOD is never set in the test environment; tests
        // must not set it either (SimCfg::default() reads it
        // concurrently).
        if std::env::var("RINGIWP_METHOD").is_err() {
            let fb = Method::IwpFixed.spec();
            assert_eq!(MethodSpec::from_env_or(fb), fb);
        }
    }

    #[test]
    fn display_matches_name() {
        let s = MethodSpec::parse("iwp:fixed+nosel").unwrap();
        assert_eq!(format!("{s}"), s.name());
    }
}
