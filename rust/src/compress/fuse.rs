//! Fused one-pass compression kernels (DESIGN.md §11).
//!
//! The multi-pass reference chain costs 3 dense sweeps per IWP step on a
//! broadcaster node — residual accumulation ([`ResidualStore::accumulate`]),
//! selection-uniform fill ([`super::select::fill_u`]), and importance
//! scoring ([`super::importance::score_and_mask`]) — plus a per-layer
//! mask merge and, after the wire phase, a support-sized residual take
//! and a separate support compaction. Every pass streams the full 25M+
//! parameter buffers through the cache again.
//!
//! This module fuses the chain into two sweeps with **bit-identical**
//! results (pinned by `rust/tests/fused_equivalence.rs` against the
//! retained multi-pass reference for every IWP method × threshold
//! policy × selection mode):
//!
//! * [`score_select_compact`] — the pre-wire kernel: one sweep computes
//!   the momentum-corrected residual update (Eq. 3), the importance
//!   `I = |r|/(|w|+ε)`, the per-layer stats rows, and the branch-free
//!   selection compare `I > u·thr` (drawing `u` inline in the exact
//!   stream order `fill_u` consumes), packing selection bits a word at
//!   a time into the caller's reusable mask. Dense passes per step
//!   drop from ≥3 to 1.
//! * [`take_compact`] — the post-wire kernel: one sweep over the shared
//!   support pops each selected coordinate's accumulated residual into
//!   the compacted payload (in support order) and zeroes residual and
//!   velocity (momentum factor masking) — fusing
//!   `ResidualStore::take_masked` with the masked schedule's support
//!   compaction, into caller-owned scratch with zero steady-state
//!   allocation. The shipped engines take the no-output sibling
//!   [`ResidualStore::clear_masked`] instead (the topology schedule
//!   compacts internally and the accounting engines discard sent
//!   values); `take_compact` is the value-carrying variant for
//!   coordinators that compact outside the schedule, pinned by the
//!   same bit-exactness tests.
//!
//! Bit-exactness argument: every fused operation is element-local and
//! executes in the same element order as the reference chain, so f32
//! results, f64 stat accumulation order, and RNG draw order are all
//! unchanged; only the number of memory passes differs. The importance
//! buffer the reference materializes is never read downstream (only its
//! per-layer stats are), so the fused kernel skips it entirely.

use super::importance::LayerStats;
use super::residual::ResidualStore;
use crate::model::ParamLayout;
use crate::sparse::BitMask;
use crate::util::rng::Rng;

/// Block size of the fused inner loops: the residual/importance phase
/// runs over fixed-size blocks (register/L1-resident, autovectorizable —
/// no RNG or f64 carry inside), and the scalar stats/selection phase
/// consumes each block while it is still hot.
const BLOCK: usize = 64;

/// The pre-wire fused kernel: residual accumulation + importance scoring
/// + randomized selection + mask packing, one sweep (DESIGN.md §11).
///
/// Per coordinate `i` of each layer `l` (threshold `thrs[l]`):
///
/// ```text
/// v_i  = m·v_i + g_i ;  r_i += v_i            (Eq. 3, momentum correction)
/// I_i  = |r_i| / (|w_i| + ε)                  (the L1 kernel's score)
/// u_i  = uniform()  (or 1.0 when !random_select)
/// select i  iff  I_i > u_i·thr                (Sec. III-C, P = I/thr)
/// ```
///
/// `mask_out` is **fully overwritten** (word-packed; stale bits cannot
/// survive), `stats_out` is cleared and refilled with one
/// [`LayerStats`] row per layer. Bit-identical to the reference chain
/// `accumulate` → `fill_u` → `score_and_mask` → per-layer mask merge.
#[allow(clippy::too_many_arguments)]
pub fn score_select_compact(
    layout: &ParamLayout,
    thrs: &[f32],
    weights: &[f32],
    grad: &[f32],
    eps: f32,
    random_select: bool,
    rng: &mut Rng,
    store: &mut ResidualStore,
    mask_out: &mut BitMask,
    stats_out: &mut Vec<LayerStats>,
) {
    let total = layout.total_params();
    assert_eq!(weights.len(), total);
    assert_eq!(grad.len(), total);
    assert_eq!(store.len(), total);
    assert_eq!(mask_out.len(), total);
    assert_eq!(thrs.len(), layout.n_layers());
    stats_out.clear();

    let momentum = store.momentum();
    let (vel, res) = store.parts_mut();
    let words = mask_out.words_mut();
    // Layers partition 0..total contiguously, so the global coordinate
    // index runs sequentially across the layer loop and selection bits
    // pack into one running word accumulator (flushed at every word
    // boundary; the trailing partial word keeps its high bits zero).
    let mut word = 0u64;
    let mut imp_block = [0.0f32; BLOCK];
    for (li, layer) in layout.layers().iter().enumerate() {
        let thr = thrs[li];
        let range = layer.range();
        let mut st = LayerStats {
            n: layer.size as f64,
            ..Default::default()
        };
        let mut i = range.start;
        while i < range.end {
            let end = (i + BLOCK).min(range.end);
            // Phase 1 — residual update + importance, element-independent.
            for (k, j) in (i..end).enumerate() {
                let v = momentum * vel[j] + grad[j];
                vel[j] = v;
                let pending = res[j] + v;
                res[j] = pending;
                imp_block[k] = pending.abs() / (weights[j].abs() + eps);
            }
            // Phase 2 — stats (f64, element order), selection, bit pack.
            for (k, j) in (i..end).enumerate() {
                let imp = imp_block[k];
                let di = imp as f64;
                st.sum += di;
                st.sumsq += di * di;
                let u = if random_select { rng.uniform() } else { 1.0 };
                if imp > u * thr {
                    word |= 1u64 << (j % 64);
                    st.n_selected += 1.0;
                }
                if j % 64 == 63 {
                    words[j / 64] = word;
                    word = 0;
                }
            }
            i = end;
        }
        stats_out.push(st);
    }
    if total % 64 != 0 {
        words[total / 64] = word;
    }
}

/// The post-wire fused kernel: masked residual take + support compaction,
/// one sweep (DESIGN.md §11).
///
/// For every set bit `i` of `shared` (ascending): push the accumulated
/// residual `r_i` onto `out` and zero `r_i` and `v_i` (momentum factor
/// masking). `out` is cleared and refilled in place (support order —
/// exactly the masked schedule's compaction order); returns whether the
/// buffer had to grow, so arena owners can feed their reallocation
/// counters. Bit-identical to `take_masked` + `compact_to_support` on
/// the transmitting node.
pub fn take_compact(store: &mut ResidualStore, shared: &BitMask, out: &mut Vec<f32>) -> bool {
    assert_eq!(shared.len(), store.len());
    let (vel, res) = store.parts_mut();
    let cap = out.capacity();
    out.clear();
    // Word-at-a-time support walk: one branch skips 64 empty
    // coordinates, and set bits pop via `trailing_zeros` / `w &= w - 1`
    // in ascending order — the same element order (hence bit-identical
    // output) as the per-bit `iter_set` walk, without its per-bit
    // iterator state.
    for (wi, &w0) in shared.words().iter().enumerate() {
        let mut w = w0;
        let base = wi * 64;
        while w != 0 {
            let i = base + w.trailing_zeros() as usize;
            w &= w - 1;
            out.push(res[i]);
            res[i] = 0.0;
            vel[i] = 0.0;
        }
    }
    out.capacity() != cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::importance::{score_and_mask, EPS};
    use crate::compress::select;
    use crate::model::{LayerKind, ParamLayout};
    use crate::util::prop::forall;

    fn layout3() -> ParamLayout {
        ParamLayout::new(
            "fuse_t",
            vec![
                // 71 params: layer boundaries straddle word boundaries.
                ("conv".into(), vec![5, 2, 3], LayerKind::Conv),
                ("bn".into(), vec![27], LayerKind::BatchNorm),
                ("fc".into(), vec![7, 2], LayerKind::Fc),
            ],
        )
    }

    /// The retained multi-pass reference: accumulate, then per layer
    /// fill_u + score_and_mask + merge into the global mask.
    #[allow(clippy::too_many_arguments)]
    fn multipass(
        layout: &ParamLayout,
        thrs: &[f32],
        weights: &[f32],
        grad: &[f32],
        random_select: bool,
        rng: &mut Rng,
        store: &mut ResidualStore,
    ) -> (BitMask, Vec<LayerStats>) {
        let total = layout.total_params();
        store.accumulate(grad);
        let mut mask = BitMask::zeros(total);
        let mut stats = Vec::new();
        let mut u = vec![1.0f32; total];
        let mut imp = vec![0.0f32; total];
        let pending: Vec<f32> = store.pending().to_vec();
        for (li, layer) in layout.layers().iter().enumerate() {
            let r = layer.range();
            select::fill_u(rng, random_select, &mut u[..layer.size]);
            let mut layer_mask = BitMask::zeros(layer.size);
            let st = score_and_mask(
                &pending[r.clone()],
                &weights[r.clone()],
                &u[..layer.size],
                thrs[li],
                EPS,
                &mut imp[..layer.size],
                &mut layer_mask,
            );
            for i in layer_mask.iter_set() {
                mask.set(r.start + i);
            }
            stats.push(st);
        }
        (mask, stats)
    }

    fn stat_bits(s: &LayerStats) -> (u64, u64, u64, u64) {
        (
            s.sum.to_bits(),
            s.sumsq.to_bits(),
            s.n_selected.to_bits(),
            s.n.to_bits(),
        )
    }

    #[test]
    fn fused_matches_multipass_reference_bitwise() {
        let layout = layout3();
        let total = layout.total_params();
        for random_select in [false, true] {
            forall("fused == multipass", 40, |gen| {
                let g = gen.vec_normal(total, 0.0, 1.0);
                let w = gen.vec_normal(total, 0.0, 0.5);
                let thrs: Vec<f32> =
                    (0..layout.n_layers()).map(|_| gen.f32_in(0.0, 0.2)).collect();
                let seed = gen.usize_in(0, 1 << 20) as u64;
                let mut rng_a = Rng::new(seed);
                let mut rng_b = Rng::new(seed);
                let mut store_a = ResidualStore::new(total, 0.9);
                let mut store_b = ResidualStore::new(total, 0.9);
                // Two steps: the second exercises warm velocity/residual.
                for _ in 0..2 {
                    let (mask_a, stats_a) = multipass(
                        &layout,
                        &thrs,
                        &w,
                        &g,
                        random_select,
                        &mut rng_a,
                        &mut store_a,
                    );
                    let mut mask_b = BitMask::zeros(total);
                    let mut stats_b = Vec::new();
                    score_select_compact(
                        &layout,
                        &thrs,
                        &w,
                        &g,
                        EPS,
                        random_select,
                        &mut rng_b,
                        &mut store_b,
                        &mut mask_b,
                        &mut stats_b,
                    );
                    assert_eq!(mask_a, mask_b, "masks diverged");
                    assert_eq!(stats_a.len(), stats_b.len());
                    for (sa, sb) in stats_a.iter().zip(&stats_b) {
                        assert_eq!(stat_bits(sa), stat_bits(sb), "stats diverged");
                    }
                    let bits = |s: &ResidualStore| -> Vec<u32> {
                        s.pending().iter().map(|v| v.to_bits()).collect()
                    };
                    assert_eq!(bits(&store_a), bits(&store_b), "residuals diverged");
                    // RNG streams must stay in lockstep across steps.
                    assert_eq!(rng_a.next_u64(), rng_b.next_u64());
                }
            });
        }
    }

    #[test]
    fn fused_overwrites_stale_mask_bits() {
        let layout = layout3();
        let total = layout.total_params();
        let mut mask = BitMask::zeros(total);
        for i in 0..total {
            mask.set(i); // all-ones: any stale bit must be cleared
        }
        let mut store = ResidualStore::new(total, 0.0);
        let mut rng = Rng::new(7);
        let thrs = vec![f32::INFINITY; layout.n_layers()];
        let g = vec![1.0f32; total];
        let w = vec![1.0f32; total];
        let mut stats = Vec::new();
        score_select_compact(
            &layout, &thrs, &w, &g, EPS, false, &mut rng, &mut store, &mut mask, &mut stats,
        );
        assert_eq!(mask.count(), 0, "infinite threshold must select nothing");
    }

    #[test]
    fn take_compact_matches_take_masked_plus_compaction() {
        forall("take_compact == take_masked", 40, |gen| {
            let n = gen.usize_in(1, 200);
            let g = gen.vec_normal(n, 0.0, 1.0);
            let mut a = ResidualStore::new(n, 0.9);
            let mut b = ResidualStore::new(n, 0.9);
            a.accumulate(&g);
            b.accumulate(&g);
            let mut mask = BitMask::zeros(n);
            for i in 0..n {
                if gen.bool() {
                    mask.set(i);
                }
            }
            let sent_a = a.take_masked(&mask);
            let mut sent_b = Vec::new();
            take_compact(&mut b, &mask, &mut sent_b);
            let fb = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
            assert_eq!(fb(&sent_a), fb(&sent_b));
            assert_eq!(fb(a.pending()), fb(b.pending()));
            // Warm buffer reuse: a second call must not grow.
            b.accumulate(&g);
            assert!(!take_compact(&mut b, &mask, &mut sent_b));
        });
    }
}
