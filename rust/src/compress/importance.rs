//! CPU importance scoring — the exact math of the L1 Pallas kernel
//! (`python/compile/kernels/importance.py`), used (a) by the large
//! synthetic-gradient experiments where PJRT round-trips per layer would
//! dominate, and (b) as the cross-check oracle for the kernel-backed path
//! (`tests/runtime_kernel.rs` asserts bit-level agreement on masks).

use crate::model::ParamLayout;
use crate::sparse::BitMask;
use crate::util::stats::mean_var_from_sums;

/// Default denominator guard (matches the artifact default).
pub const EPS: f32 = 1e-8;

/// Per-layer importance statistics — the kernel's `stats` output
/// aggregated per layer: inputs to the Eq. 4 controller and to Fig. 4.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerStats {
    /// ΣI — sum of importance values.
    pub sum: f64,
    /// ΣI² — sum of squared importance values.
    pub sumsq: f64,
    /// Number of coordinates the mask selected.
    pub n_selected: f64,
    /// Number of coordinates scored.
    pub n: f64,
}

impl LayerStats {
    /// Mean importance (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n > 0.0 {
            self.sum / self.n
        } else {
            0.0
        }
    }

    /// Population variance of the importance values.
    pub fn var(&self) -> f64 {
        mean_var_from_sums(self.sum, self.sumsq, self.n).1
    }

    /// The Eq. 4 dispersion factor.
    pub fn var_over_mean(&self) -> f64 {
        let m = self.mean();
        if m.abs() < 1e-30 {
            0.0
        } else {
            self.var() / m
        }
    }

    /// Selected fraction `n_selected / n` (0 when empty).
    pub fn density(&self) -> f64 {
        if self.n > 0.0 {
            self.n_selected / self.n
        } else {
            0.0
        }
    }

    /// Accumulate another buffer's stats into this one (pure sums).
    pub fn merge(&mut self, other: &LayerStats) {
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.n_selected += other.n_selected;
        self.n += other.n;
    }

    /// From the kernel's raw `[ΣI, ΣI², n_sel, n]` row.
    pub fn from_kernel(stats: &[f32]) -> Self {
        LayerStats {
            sum: stats[0] as f64,
            sumsq: stats[1] as f64,
            n_selected: stats[2] as f64,
            n: stats[3] as f64,
        }
    }
}

/// `out[i] = |g[i]| / (|w[i]| + eps)` — one flat buffer.
pub fn scores_into(g: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert!(g.len() == w.len() && g.len() == out.len());
    for i in 0..g.len() {
        out[i] = g[i].abs() / (w[i].abs() + eps);
    }
}

/// Allocating variant of [`scores_into`].
pub fn scores(g: &[f32], w: &[f32], eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; g.len()];
    scores_into(g, w, eps, &mut out);
    out
}

/// Masked scoring with randomized selection: `mask = I > u*thr` where
/// `u == 1` disables the random path (the kernel's exact semantics).
/// Returns per-buffer stats like the kernel's stats row.
pub fn score_and_mask(
    g: &[f32],
    w: &[f32],
    u: &[f32],
    thr: f32,
    eps: f32,
    imp_out: &mut [f32],
    mask_out: &mut BitMask,
) -> LayerStats {
    assert!(g.len() == w.len() && g.len() == u.len() && g.len() == imp_out.len());
    assert_eq!(mask_out.len(), g.len());
    let mut stats = LayerStats::default();
    for i in 0..g.len() {
        let imp = g[i].abs() / (w[i].abs() + eps);
        imp_out[i] = imp;
        stats.sum += imp as f64;
        stats.sumsq += (imp as f64) * (imp as f64);
        if imp > u[i] * thr {
            mask_out.set(i);
            stats.n_selected += 1.0;
        }
    }
    stats.n = g.len() as f64;
    stats
}

/// Per-layer stats over a whole model buffer (no masking) — the Fig. 2/3/4
/// measurement pass.
pub fn layer_stats(layout: &ParamLayout, imp: &[f32]) -> Vec<LayerStats> {
    let mut out = Vec::new();
    layer_stats_into(layout, imp, &mut out);
    out
}

/// [`layer_stats`] into a caller-owned buffer — the per-step measurement
/// hooks (`SimEngine::importance_snapshot`) reuse one buffer instead of
/// allocating per call.
pub fn layer_stats_into(layout: &ParamLayout, imp: &[f32], out: &mut Vec<LayerStats>) {
    assert_eq!(imp.len(), layout.total_params());
    out.clear();
    out.extend(layout.layers().iter().map(|layer| {
        let mut s = LayerStats::default();
        for &v in &imp[layer.range()] {
            s.sum += v as f64;
            s.sumsq += (v as f64) * (v as f64);
        }
        s.n = layer.size as f64;
        s
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerKind, ParamLayout};
    use crate::util::prop::forall;

    #[test]
    fn scores_formula() {
        let got = scores(&[1.0, -2.0, 0.0], &[0.5, -0.5, 2.0], 0.0);
        assert_eq!(got, vec![2.0, 4.0, 0.0]);
    }

    #[test]
    fn eps_guards_zero_weight() {
        let got = scores(&[1.0], &[0.0], 1e-8);
        assert!(got[0].is_finite() && got[0] > 1e7);
    }

    #[test]
    fn score_and_mask_hard_threshold() {
        let g = [1.0f32, 0.01, 0.5];
        let w = [1.0f32, 1.0, 1.0];
        let u = [1.0f32; 3];
        let mut imp = [0.0f32; 3];
        let mut mask = BitMask::zeros(3);
        let s = score_and_mask(&g, &w, &u, 0.1, 0.0, &mut imp, &mut mask);
        assert!(mask.get(0) && !mask.get(1) && mask.get(2));
        assert_eq!(s.n_selected, 2.0);
        assert_eq!(s.n, 3.0);
    }

    #[test]
    fn stats_match_direct_computation_property() {
        forall("score stats == welford", 50, |gen| {
            let n = gen.usize_in(1, 400);
            let g = gen.vec_normal(n, 0.0, 1.0);
            let w = gen.vec_normal(n, 0.0, 1.0);
            let u = vec![1.0f32; n];
            let mut imp = vec![0.0f32; n];
            let mut mask = BitMask::zeros(n);
            let s = score_and_mask(&g, &w, &u, 0.05, EPS, &mut imp, &mut mask);
            let mean_direct =
                imp.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            assert!((s.mean() - mean_direct).abs() < 1e-6 * mean_direct.abs().max(1.0));
            assert_eq!(s.n_selected as usize, mask.count());
        });
    }

    #[test]
    fn layer_stats_partition_global_sum() {
        let layout = ParamLayout::new(
            "t",
            vec![
                ("a".into(), vec![10], LayerKind::Fc),
                ("b".into(), vec![5], LayerKind::Bias),
            ],
        );
        let imp: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let per = layer_stats(&layout, &imp);
        let total: f64 = per.iter().map(|s| s.sum).sum();
        assert_eq!(total, (0..15).sum::<i32>() as f64);
        assert_eq!(per[0].n, 10.0);
        assert_eq!(per[1].n, 5.0);
    }

    #[test]
    fn var_over_mean_of_constant_is_zero() {
        let s = LayerStats {
            sum: 100.0,
            sumsq: 100.0,
            n_selected: 0.0,
            n: 100.0,
        };
        assert!(s.var_over_mean().abs() < 1e-9);
    }
}
