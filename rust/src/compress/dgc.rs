//! Deep Gradient Compression baseline (Lin et al., 2017) — per-node top-k
//! selection with momentum-corrected residuals.
//!
//! This is the method the paper argues *breaks on rings* (Sec. II): every
//! node picks its own top-k support, so as chunks travel the ring the
//! union of supports grows — "if we took the top 1% gradient on each
//! node… the worst case is that the top k gradient is 2%" per hop, i.e.
//! density → min(1, k·N/len).  `ring::sparse` measures exactly this;
//! `exp::density` turns it into the density-growth figure.

use super::residual::ResidualStore;
use crate::sparse::SparseVec;

/// DGC compressor state for one node.
#[derive(Debug, Clone)]
pub struct Dgc {
    /// Fraction of coordinates transmitted per step (paper's 1% -> 0.01).
    pub density: f64,
    store: ResidualStore,
}

impl Dgc {
    /// Fresh DGC state over `len` coordinates at the given target
    /// density and residual momentum.
    pub fn new(len: usize, density: f64, momentum: f32) -> Self {
        assert!((0.0..=1.0).contains(&density));
        Dgc {
            density,
            store: ResidualStore::new(len, momentum),
        }
    }

    /// Warm-up aware density: DGC ramps 25% -> 6.25% -> … -> target over
    /// the first epochs.
    pub fn density_at_epoch(target: f64, epoch: usize, warmup_epochs: usize) -> f64 {
        if epoch >= warmup_epochs {
            return target;
        }
        // Geometric: start at 0.25 and interpolate towards target.
        let start: f64 = 0.25;
        let frac = epoch as f64 / warmup_epochs.max(1) as f64;
        start * (target / start).powf(frac)
    }

    /// One step: accumulate the local gradient, emit the top-k sparse
    /// update and clear those coordinates.
    pub fn step(&mut self, grad: &[f32]) -> SparseVec {
        self.store.accumulate(grad);
        let k = ((self.store.len() as f64) * self.density).ceil() as usize;
        let sparse = SparseVec::top_k(self.store.pending(), k);
        // Momentum factor masking on the transmitted support — the
        // values already live in `sparse`, so zero without extracting
        // (no per-step Vec, DESIGN.md §11).
        let mut mask = crate::sparse::BitMask::zeros(self.store.len());
        for &i in &sparse.idx {
            mask.set(i as usize);
        }
        self.store.clear_masked(&mask);
        sparse
    }

    /// L2 norm of the unsent residual (staleness diagnostic).
    pub fn residual_norm(&self) -> f64 {
        self.store.residual_norm()
    }

    /// The underlying residual store (elastic-membership migration:
    /// a departing node's pending DGC momentum is handed off or
    /// rescaled through here — DESIGN.md §15).
    pub fn store(&self) -> &ResidualStore {
        &self.store
    }

    /// Mutable access to the underlying residual store (see
    /// [`Dgc::store`]).
    pub fn store_mut(&mut self) -> &mut ResidualStore {
        &mut self.store
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_requested_density() {
        let mut d = Dgc::new(1000, 0.01, 0.0);
        let g: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let s = d.step(&g);
        assert_eq!(s.nnz(), 10);
    }

    #[test]
    fn residuals_flush_eventually() {
        // A large coordinate not initially selected keeps accumulating
        // until it wins top-k.
        let mut d = Dgc::new(100, 0.01, 0.0); // k = 1
        let mut g = vec![0.0f32; 100];
        g[7] = 0.4; // runner-up each step
        g[3] = 1.0; // winner each step
        let s1 = d.step(&g);
        assert_eq!(s1.idx, vec![3]);
        // After enough steps, coord 7's residual (0.4 per step) exceeds
        // coord 3's fresh 1.0: 3 steps -> 1.2.
        let _ = d.step(&g);
        let s3 = d.step(&g);
        assert_eq!(s3.idx, vec![7], "residual accumulation must flush");
    }

    #[test]
    fn warmup_density_ramps_down() {
        let d0 = Dgc::density_at_epoch(0.001, 0, 4);
        let d2 = Dgc::density_at_epoch(0.001, 2, 4);
        let d4 = Dgc::density_at_epoch(0.001, 4, 4);
        assert!((d0 - 0.25).abs() < 1e-9);
        assert!(d2 < d0 && d2 > d4);
        assert_eq!(d4, 0.001);
    }

    #[test]
    fn transmitted_plus_residual_conserves_mass() {
        let mut d = Dgc::new(50, 0.1, 0.0);
        let g: Vec<f32> = (0..50).map(|i| i as f32 / 10.0).collect();
        let injected: f64 = g.iter().map(|&v| v as f64).sum();
        let s = d.step(&g);
        let sent: f64 = s.val.iter().map(|&v| v as f64).sum();
        // residual_norm is L2; recompute pending sum via another take.
        let mut store_sum = 0.0;
        let dense = {
            let mut m = crate::sparse::BitMask::zeros(50);
            for i in 0..50 {
                m.set(i);
            }
            d.store.take_masked(&m)
        };
        for v in dense {
            store_sum += v as f64;
        }
        assert!((injected - sent - store_sum).abs() < 1e-4);
    }
}
