//! Random gradient selection (Sec. III-C): sub-threshold gradients still
//! transmit with probability `P(update) = importance / threshold`,
//! countering gradient staleness ("most of the parameters are updated
//! between 100-300 steps; the dated gradient will lead to errors in the
//! direction of parameter update").
//!
//! Mechanism: the kernel's branch-free compare `I > u*thr` needs a `u`
//! buffer — `fill_u` draws it (or fills 1.0 when the feature is off).

use crate::util::rng::Rng;

/// Fill the selection buffer: uniforms when enabled, 1.0 when disabled
/// (disabled == exact hard threshold in the kernel/CPU compare).
pub fn fill_u(rng: &mut Rng, enabled: bool, out: &mut [f32]) {
    if enabled {
        rng.fill_uniform(out);
    } else {
        out.iter_mut().for_each(|v| *v = 1.0);
    }
}

/// Expected selection probability for a coordinate of importance `imp`
/// under threshold `thr` (the paper's P(update), clamped to [0,1]).
pub fn p_update(imp: f32, thr: f32) -> f32 {
    if thr <= 0.0 {
        1.0
    } else {
        (imp / thr).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn disabled_gives_hard_threshold() {
        let mut rng = Rng::new(1);
        let mut u = vec![0.0f32; 8];
        fill_u(&mut rng, false, &mut u);
        assert!(u.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn enabled_matches_p_update_empirically() {
        // importance fixed at 0.3 * thr -> ~30% acceptance.
        let mut rng = Rng::new(5);
        let n = 100_000;
        let mut u = vec![0.0f32; n];
        fill_u(&mut rng, true, &mut u);
        let thr = 0.1f32;
        let imp = 0.03f32;
        let selected = u.iter().filter(|&&ui| imp > ui * thr).count();
        let rate = selected as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn p_update_clamps() {
        assert_eq!(p_update(5.0, 0.1), 1.0);
        assert!((p_update(0.05, 0.1) - 0.5).abs() < 1e-6);
        assert_eq!(p_update(0.0, 0.0), 1.0);
    }

    #[test]
    fn super_threshold_always_selected_property() {
        forall("I > thr always transmits under any u", 100, |g| {
            let thr = g.f32_in(0.001, 0.5);
            let imp = thr * g.f32_in(1.001, 10.0);
            let u = g.f32_in(0.0, 1.0);
            assert!(imp > u * thr);
        });
    }
}
