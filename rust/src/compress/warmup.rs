//! Warm-up training (Sec. IV-A, inherited from DGC): aggressive pruning
//! from step 0 hurts early optimisation, so sparsity ramps up over the
//! first epochs — implemented as a multiplier < 1 on the importance
//! threshold that exponentially approaches 1.

/// Warm-up schedule over epochs.
#[derive(Debug, Clone, Copy)]
pub struct Warmup {
    /// Number of warm-up epochs (0 disables).
    pub epochs: usize,
    /// Threshold multiplier at epoch 0 (e.g. 0.1 -> 10x laxer threshold).
    pub start_mult: f32,
}

impl Default for Warmup {
    fn default() -> Self {
        Warmup {
            epochs: 4,
            start_mult: 0.1,
        }
    }
}

impl Warmup {
    /// Disabled warm-up (multiplier 1 everywhere).
    pub fn none() -> Self {
        Warmup {
            epochs: 0,
            start_mult: 1.0,
        }
    }

    /// Threshold multiplier at `epoch` — exponential ramp from
    /// `start_mult` to 1.0 across `epochs` (DGC ramps density 25%, 6.25%,
    /// …; the threshold-domain equivalent is a geometric multiplier).
    pub fn multiplier(&self, epoch: usize) -> f32 {
        if self.epochs == 0 || epoch >= self.epochs {
            return 1.0;
        }
        let frac = epoch as f32 / self.epochs as f32;
        // Geometric interpolation start_mult^(1-frac).
        self.start_mult.powf(1.0 - frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramps_from_start_to_one() {
        let w = Warmup {
            epochs: 4,
            start_mult: 0.1,
        };
        assert!((w.multiplier(0) - 0.1).abs() < 1e-6);
        assert!(w.multiplier(1) > w.multiplier(0));
        assert!(w.multiplier(3) < 1.0);
        assert_eq!(w.multiplier(4), 1.0);
        assert_eq!(w.multiplier(100), 1.0);
    }

    #[test]
    fn none_is_identity() {
        let w = Warmup::none();
        assert_eq!(w.multiplier(0), 1.0);
    }

    #[test]
    fn monotone_nondecreasing() {
        let w = Warmup::default();
        let mut prev = 0.0;
        for e in 0..=w.epochs {
            let m = w.multiplier(e);
            assert!(m >= prev);
            prev = m;
        }
    }
}
