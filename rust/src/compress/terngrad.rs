//! TernGrad baseline (Wen et al., 2017) — Table I comparator.
//!
//! Each gradient coordinate is stochastically rounded to
//! `s_t * sign(g) * b` with `b ∈ {0, 1}`, `P(b=1) = |g| / s_t`, where
//! `s_t = max|g|` per layer (scaler sharing). The estimator is unbiased:
//! `E[decode] = g`. Wire format: 2 bits/coordinate + one f32 scale per
//! layer.

use crate::model::ParamLayout;
use crate::util::rng::Rng;

/// Ternary-quantized gradient for one flat buffer.
#[derive(Debug, Clone)]
pub struct TernGrad {
    /// Coordinate count of the encoded buffer.
    pub len: usize,
    /// Per-layer scales s_t.
    pub scales: Vec<f32>,
    /// 2-bit codes packed 4/byte: 0 -> 0, 1 -> +1, 2 -> -1.
    pub codes: Vec<u8>,
}

const CODE_ZERO: u8 = 0;
const CODE_POS: u8 = 1;
const CODE_NEG: u8 = 2;

impl TernGrad {
    /// Quantize `grad` with per-layer scales (stochastic, unbiased).
    pub fn encode(grad: &[f32], layout: &ParamLayout, rng: &mut Rng) -> Self {
        assert_eq!(grad.len(), layout.total_params());
        let mut scales = Vec::with_capacity(layout.n_layers());
        let mut codes = vec![0u8; grad.len().div_ceil(4)];
        for layer in layout.layers() {
            let g = &grad[layer.range()];
            let s = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            scales.push(s);
            if s == 0.0 {
                continue; // all codes stay zero
            }
            for (k, &v) in g.iter().enumerate() {
                let i = layer.offset + k;
                let p = v.abs() / s;
                let code = if rng.uniform() < p {
                    if v >= 0.0 {
                        CODE_POS
                    } else {
                        CODE_NEG
                    }
                } else {
                    CODE_ZERO
                };
                codes[i / 4] |= code << ((i % 4) * 2);
            }
        }
        TernGrad {
            len: grad.len(),
            scales,
            codes,
        }
    }

    /// Decode back to a dense f32 buffer.
    pub fn decode(&self, layout: &ParamLayout) -> Vec<f32> {
        assert_eq!(self.len, layout.total_params());
        let mut out = vec![0.0f32; self.len];
        for (li, layer) in layout.layers().iter().enumerate() {
            let s = self.scales[li];
            for i in layer.range() {
                let code = (self.codes[i / 4] >> ((i % 4) * 2)) & 0b11;
                out[i] = match code {
                    CODE_POS => s,
                    CODE_NEG => -s,
                    _ => 0.0,
                };
            }
        }
        out
    }

    /// Bytes on the wire: packed codes + per-layer scales + header.
    pub fn wire_bytes(&self) -> u64 {
        crate::sparse::HEADER_BYTES + self.codes.len() as u64 + 4 * self.scales.len() as u64
    }
}

/// Single-scale ternary quantization of a compacted support payload —
/// the `+tern` pipeline stage (DESIGN.md §12). Once the shared mask is
/// known, each node's compacted residuals quantize against one shared
/// scale `s = max|v|` (the support is a cross-layer slice, so per-layer
/// scaler sharing does not apply); the same unbiased stochastic
/// rounding as [`TernGrad`]. Ternary values are not closed under
/// addition, so the blobs spread whole and decode-sum at full precision
/// on every node.
#[derive(Debug, Clone)]
pub struct TernBlob {
    /// Coordinate count of the encoded payload (the shared support nnz).
    pub len: usize,
    /// Shared scale s = max|v|.
    pub scale: f32,
    /// 2-bit codes packed 4/byte: 0 -> 0, 1 -> +1, 2 -> -1.
    pub codes: Vec<u8>,
}

impl TernBlob {
    /// Quantize a compacted payload (stochastic, unbiased).
    pub fn encode(values: &[f32], rng: &mut Rng) -> Self {
        let mut codes = vec![0u8; values.len().div_ceil(4)];
        let scale = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if scale > 0.0 {
            for (i, &v) in values.iter().enumerate() {
                let p = v.abs() / scale;
                let code = if rng.uniform() < p {
                    if v >= 0.0 {
                        CODE_POS
                    } else {
                        CODE_NEG
                    }
                } else {
                    CODE_ZERO
                };
                codes[i / 4] |= code << ((i % 4) * 2);
            }
        }
        TernBlob {
            len: values.len(),
            scale,
            codes,
        }
    }

    /// Add the decoded payload into `acc` (the receive-side decode-sum;
    /// `acc` is support-length, aligned with the encode input).
    pub fn add_decoded_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.len);
        for (i, a) in acc.iter_mut().enumerate() {
            let code = (self.codes[i / 4] >> ((i % 4) * 2)) & 0b11;
            *a += match code {
                CODE_POS => self.scale,
                CODE_NEG => -self.scale,
                _ => 0.0,
            };
        }
    }

    /// Bytes on the wire for an `nnz`-coordinate payload: header +
    /// packed codes + one f32 scale. Shape-determined, so the
    /// accounting engines and [`crate::net::CostModel`] price blobs
    /// without encoding.
    pub fn wire_bytes_for(nnz: usize) -> u64 {
        crate::sparse::HEADER_BYTES + nnz.div_ceil(4) as u64 + 4
    }

    /// Bytes this blob occupies on the wire.
    pub fn wire_bytes(&self) -> u64 {
        Self::wire_bytes_for(self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerKind, ParamLayout};

    fn layout(n: usize) -> ParamLayout {
        ParamLayout::new("t", vec![("a".into(), vec![n], LayerKind::Fc)])
    }

    #[test]
    fn decode_values_in_ternary_set() {
        let mut rng = Rng::new(1);
        let l = layout(64);
        let g: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 10.0).collect();
        let t = TernGrad::encode(&g, &l, &mut rng);
        let d = t.decode(&l);
        let s = t.scales[0];
        for &v in &d {
            assert!(v == 0.0 || (v - s).abs() < 1e-6 || (v + s).abs() < 1e-6);
        }
    }

    #[test]
    fn unbiased_estimator() {
        let mut rng = Rng::new(2);
        let l = layout(4);
        let g = vec![0.5f32, -0.25, 1.0, 0.0];
        let trials = 20_000;
        let mut acc = vec![0.0f64; 4];
        for _ in 0..trials {
            let t = TernGrad::encode(&g, &l, &mut rng);
            for (a, v) in acc.iter_mut().zip(t.decode(&l)) {
                *a += v as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - g[i] as f64).abs() < 0.02,
                "coord {i}: E={mean} vs g={}",
                g[i]
            );
        }
    }

    #[test]
    fn max_magnitude_always_transmits() {
        let mut rng = Rng::new(3);
        let l = layout(3);
        let g = vec![0.1f32, -2.0, 0.1];
        for _ in 0..50 {
            let t = TernGrad::encode(&g, &l, &mut rng);
            let d = t.decode(&l);
            assert!((d[1] + 2.0).abs() < 1e-6); // P = |g|/s = 1
        }
    }

    #[test]
    fn wire_bytes_approx_quarter_byte_per_coord() {
        let mut rng = Rng::new(4);
        let l = layout(10_000);
        let g = vec![0.1f32; 10_000];
        let t = TernGrad::encode(&g, &l, &mut rng);
        // 10k coords -> 2500 code bytes + 4 scale + 9 header.
        assert_eq!(t.wire_bytes(), 2500 + 4 + 9);
        // ~16x smaller than 40000 dense bytes.
        assert!((10_000 * 4) as f64 / t.wire_bytes() as f64 > 15.0);
    }

    #[test]
    fn zero_layer_encodes_to_zero() {
        let mut rng = Rng::new(5);
        let l = layout(16);
        let g = vec![0.0f32; 16];
        let t = TernGrad::encode(&g, &l, &mut rng);
        assert!(t.decode(&l).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tern_blob_is_unbiased_and_shape_priced() {
        let mut rng = Rng::new(9);
        let values = vec![0.5f32, -0.25, 1.0, 0.0, 0.75];
        let trials = 20_000;
        let mut acc = vec![0.0f32; 5];
        for _ in 0..trials {
            let b = TernBlob::encode(&values, &mut rng);
            assert_eq!(b.wire_bytes(), TernBlob::wire_bytes_for(5));
            b.add_decoded_into(&mut acc);
        }
        for (i, &a) in acc.iter().enumerate() {
            let mean = a as f64 / trials as f64;
            assert!(
                (mean - values[i] as f64).abs() < 0.02,
                "coord {i}: E={mean} vs v={}",
                values[i]
            );
        }
        // 5 coords -> 2 code bytes + 4 scale + 9 header.
        assert_eq!(TernBlob::wire_bytes_for(5), 2 + 4 + 9);
        // Zero payload encodes to zero and decodes to zero.
        let z = TernBlob::encode(&[0.0; 8], &mut rng);
        let mut acc = vec![1.0f32; 8];
        z.add_decoded_into(&mut acc);
        assert!(acc.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn multi_layer_scales_are_per_layer() {
        let l = ParamLayout::new(
            "t",
            vec![
                ("a".into(), vec![4], LayerKind::Fc),
                ("b".into(), vec![4], LayerKind::Fc),
            ],
        );
        let mut rng = Rng::new(6);
        let g = vec![1.0, 1.0, 1.0, 1.0, 10.0, 10.0, 10.0, 10.0];
        let t = TernGrad::encode(&g, &l, &mut rng);
        assert_eq!(t.scales, vec![1.0, 10.0]);
    }
}
