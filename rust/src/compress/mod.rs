//! Gradient compression policies — the paper's contribution and its
//! Table-I comparators.
//!
//! * `importance` — CPU mirror of the L1 Pallas kernel: `I = |g|/(|w|+ε)`
//!   scoring + per-layer stats (the kernel-backed path lives in
//!   `runtime::kernels` and is cross-validated against this in tests).
//! * `threshold` — fixed and layer-wise (Eq. 4) threshold controllers.
//! * `select` — random gradient selection, `P(update) = I/thr` (Sec. III-C).
//! * `residual` — local accumulation with momentum (Eq. 3) + momentum
//!   factor masking.
//! * `fuse` — single-pass fused kernels over the chain above: one sweep
//!   for accumulate + score + select (and one support-sized sweep for
//!   take + compact), bit-identical to the multi-pass reference
//!   (DESIGN.md §11) — the engines' hot path.
//! * `clip` / `warmup` — DGC-inherited tricks the paper also applies.
//! * `terngrad` / `dgc` — the baselines the paper compares against.
//! * `quant` — the parametric `+q:<bits>` low-precision payload stage
//!   (bf16/f16/q8/q4/q2, DESIGN.md §17); `+tern` is the pinned alias of
//!   its 2-bit special case.
//! * `spec` / `pipeline` — the compressor strategy subsystem
//!   (DESIGN.md §12): a string-spec grammar naming every point in the
//!   scoring × policy × selection × store × quantization family, and
//!   the [`Compressor`] trait both engines reduce through. The legacy
//!   [`Method`] enum survives as the Table-I alias layer; each value
//!   maps to a canonical spec ([`Method::spec`]) that runs
//!   bit-identically to the pre-refactor engines.

pub mod clip;
pub mod dgc;
pub mod fuse;
pub mod importance;
pub mod pipeline;
pub mod quant;
pub mod residual;
pub mod select;
pub mod spec;
pub mod terngrad;
pub mod threshold;
pub mod warmup;

pub use pipeline::{Compressor, SimCtx, StageCfg, TrainCtx, WireOutcome};
pub use quant::{QBlob, QuantWidth};
pub use spec::{DgcSelect, IwpPolicy, MethodSpec, SpecHead};

/// The training methods of Table I (plus DGC for the §II density claim)
/// — the legacy alias layer over the spec grammar (`compress::spec`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Dense synchronous SGD over ring all-reduce.
    Baseline,
    /// TernGrad ternary quantization.
    TernGrad,
    /// Importance-weighted pruning, one global threshold ("Fix Threshold").
    IwpFixed,
    /// Importance-weighted pruning with the Eq. 4 layer-wise controller.
    IwpLayerwise,
    /// Deep Gradient Compression top-k (per-node masks; densifies on ring).
    Dgc,
}

impl Method {
    /// Parse a CLI/config method name (accepts the common aliases).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "baseline" | "dense" => Method::Baseline,
            "terngrad" => Method::TernGrad,
            "iwp-fixed" | "fixed" => Method::IwpFixed,
            "iwp-layerwise" | "layerwise" => Method::IwpLayerwise,
            "dgc" | "topk" => Method::Dgc,
            other => anyhow::bail!(
                "unknown method `{other}` (baseline|terngrad|iwp-fixed|iwp-layerwise|dgc)"
            ),
        })
    }

    /// Canonical CLI/CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::TernGrad => "terngrad",
            Method::IwpFixed => "iwp-fixed",
            Method::IwpLayerwise => "iwp-layerwise",
            Method::Dgc => "dgc",
        }
    }

    /// Paper's Table-I label.
    pub fn table_label(&self) -> &'static str {
        match self {
            Method::Baseline => "Baseline",
            Method::TernGrad => "TernGrad",
            Method::IwpFixed => "Fix Threshold",
            Method::IwpLayerwise => "Layerwise Threshold",
            Method::Dgc => "DGC top-k",
        }
    }

    /// Every method, in Table-I row order.
    pub fn all() -> [Method; 5] {
        [
            Method::Baseline,
            Method::TernGrad,
            Method::IwpFixed,
            Method::IwpLayerwise,
            Method::Dgc,
        ]
    }

    /// The canonical [`MethodSpec`] this legacy value maps to
    /// (`baseline -> dense`, `dgc -> dgc:topk`, …) — pinned bit-for-bit
    /// against the pre-refactor engines by
    /// `rust/tests/compressor_equivalence.rs`.
    pub fn spec(self) -> MethodSpec {
        MethodSpec::bare(match self {
            Method::Baseline => SpecHead::Dense,
            Method::TernGrad => SpecHead::Terngrad,
            Method::IwpFixed => SpecHead::Iwp(IwpPolicy::Fixed),
            Method::IwpLayerwise => SpecHead::Iwp(IwpPolicy::Layerwise),
            Method::Dgc => SpecHead::Dgc(DgcSelect::TopK),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert_eq!(Method::parse("layerwise").unwrap(), Method::IwpLayerwise);
        assert!(Method::parse("nope").is_err());
    }
}
