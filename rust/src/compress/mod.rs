//! Gradient compression policies — the paper's contribution and its
//! Table-I comparators.
//!
//! * `importance` — CPU mirror of the L1 Pallas kernel: `I = |g|/(|w|+ε)`
//!   scoring + per-layer stats (the kernel-backed path lives in
//!   `runtime::kernels` and is cross-validated against this in tests).
//! * `threshold` — fixed and layer-wise (Eq. 4) threshold controllers.
//! * `select` — random gradient selection, `P(update) = I/thr` (Sec. III-C).
//! * `residual` — local accumulation with momentum (Eq. 3) + momentum
//!   factor masking.
//! * `fuse` — single-pass fused kernels over the chain above: one sweep
//!   for accumulate + score + select (and one support-sized sweep for
//!   take + compact), bit-identical to the multi-pass reference
//!   (DESIGN.md §11) — the engines' hot path.
//! * `clip` / `warmup` — DGC-inherited tricks the paper also applies.
//! * `terngrad` / `dgc` — the baselines the paper compares against.

pub mod clip;
pub mod dgc;
pub mod fuse;
pub mod importance;
pub mod residual;
pub mod select;
pub mod terngrad;
pub mod threshold;
pub mod warmup;

/// The training methods of Table I (plus DGC for the §II density claim).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Dense synchronous SGD over ring all-reduce.
    Baseline,
    /// TernGrad ternary quantization.
    TernGrad,
    /// Importance-weighted pruning, one global threshold ("Fix Threshold").
    IwpFixed,
    /// Importance-weighted pruning with the Eq. 4 layer-wise controller.
    IwpLayerwise,
    /// Deep Gradient Compression top-k (per-node masks; densifies on ring).
    Dgc,
}

impl Method {
    /// Parse a CLI/config method name (accepts the common aliases).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "baseline" | "dense" => Method::Baseline,
            "terngrad" => Method::TernGrad,
            "iwp-fixed" | "fixed" => Method::IwpFixed,
            "iwp-layerwise" | "layerwise" => Method::IwpLayerwise,
            "dgc" | "topk" => Method::Dgc,
            other => anyhow::bail!(
                "unknown method `{other}` (baseline|terngrad|iwp-fixed|iwp-layerwise|dgc)"
            ),
        })
    }

    /// Canonical CLI/CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::TernGrad => "terngrad",
            Method::IwpFixed => "iwp-fixed",
            Method::IwpLayerwise => "iwp-layerwise",
            Method::Dgc => "dgc",
        }
    }

    /// Paper's Table-I label.
    pub fn table_label(&self) -> &'static str {
        match self {
            Method::Baseline => "Baseline",
            Method::TernGrad => "TernGrad",
            Method::IwpFixed => "Fix Threshold",
            Method::IwpLayerwise => "Layerwise Threshold",
            Method::Dgc => "DGC top-k",
        }
    }

    /// Every method, in Table-I row order.
    pub fn all() -> [Method; 5] {
        [
            Method::Baseline,
            Method::TernGrad,
            Method::IwpFixed,
            Method::IwpLayerwise,
            Method::Dgc,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert_eq!(Method::parse("layerwise").unwrap(), Method::IwpLayerwise);
        assert!(Method::parse("nope").is_err());
    }
}
