//! Residual accumulation with momentum (Eq. 3) — unsent gradients are not
//! dropped; they accumulate locally and ride along once selected.
//!
//! Implements DGC-style *momentum correction*: instead of accumulating the
//! raw gradient and applying momentum globally (which Eq. 2 shows would
//! mis-weight stale coordinates), each node keeps
//!
//! ```text
//! v_t = m * v_{t-1} + g_t          (per-node momentum buffer)
//! r_t = r_{t-1} + v_t              (residual accumulation)
//! transmit r_t ⊙ Mask; r_t ⊙ ¬Mask stays; v ⊙ Mask is cleared
//! ```
//!
//! the last step being *momentum factor masking*, which stops stale
//! momentum from pushing a just-transmitted coordinate twice.

/// Per-node residual + momentum store over a flat parameter buffer.
#[derive(Debug, Clone)]
pub struct ResidualStore {
    momentum: f32,
    /// Momentum-corrected velocity v.
    vel: Vec<f32>,
    /// Accumulated unsent gradient r.
    res: Vec<f32>,
}

impl ResidualStore {
    /// Zeroed store over `len` coordinates with the given momentum.
    pub fn new(len: usize, momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum));
        ResidualStore {
            momentum,
            vel: vec![0.0; len],
            res: vec![0.0; len],
        }
    }

    /// Number of coordinates tracked.
    pub fn len(&self) -> usize {
        self.res.len()
    }

    /// True for a zero-length store.
    pub fn is_empty(&self) -> bool {
        self.res.is_empty()
    }

    /// Fold one local gradient into the store (velocity + residual).
    pub fn accumulate(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.res.len());
        for i in 0..grad.len() {
            self.vel[i] = self.momentum * self.vel[i] + grad[i];
            self.res[i] += self.vel[i];
        }
    }

    /// The value that *would* transmit per coordinate (for importance
    /// scoring — the paper scores the accumulated update, Sec. III-B).
    pub fn pending(&self) -> &[f32] {
        &self.res
    }

    /// The momentum factor this store was built with.
    pub fn momentum(&self) -> f32 {
        self.momentum
    }

    /// Split borrow of `(velocity, residual)` for the fused one-pass
    /// kernels (`compress::fuse`, DESIGN.md §11), which interleave the
    /// [`ResidualStore::accumulate`] update with importance scoring in a
    /// single sweep.
    pub(crate) fn parts_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut self.vel, &mut self.res)
    }

    /// Extract the selected coordinates for transmission, zeroing their
    /// residual AND velocity (momentum factor masking). `mask.get(i)` true
    /// means coordinate i is transmitted this step.
    pub fn take_masked(&mut self, mask: &crate::sparse::BitMask) -> Vec<f32> {
        assert_eq!(mask.len(), self.res.len());
        let mut out = Vec::with_capacity(mask.count());
        for i in mask.iter_set() {
            out.push(self.res[i]);
            self.res[i] = 0.0;
            self.vel[i] = 0.0;
        }
        out
    }

    /// [`ResidualStore::take_masked`] without materializing the sent
    /// values: zeroes residual and velocity on the mask support in one
    /// sweep. The accounting-only engines (`exp::simrun`) discard the
    /// transmitted values, so this replaces a per-node `Vec` allocation
    /// per step on their hot path. For the value-carrying fusion see
    /// `compress::fuse::take_compact`.
    pub fn clear_masked(&mut self, mask: &crate::sparse::BitMask) {
        assert_eq!(mask.len(), self.res.len());
        for i in mask.iter_set() {
            self.res[i] = 0.0;
            self.vel[i] = 0.0;
        }
    }

    /// Take everything (dense baseline path).
    pub fn take_all(&mut self) -> Vec<f32> {
        let out = self.res.clone();
        self.res.iter_mut().for_each(|v| *v = 0.0);
        self.vel.iter_mut().for_each(|v| *v = 0.0);
        out
    }

    /// L2 norm of the unsent residual (diagnostic: gradient staleness mass).
    pub fn residual_norm(&self) -> f64 {
        self.res.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Sum of the unsent residual (the conserved quantity the recovery
    /// invariants track — DESIGN.md §15), accumulated in f64 index
    /// order.
    pub fn residual_sum(&self) -> f64 {
        self.res.iter().map(|&v| v as f64).sum()
    }

    /// Fold another store's pending state into this one (residual-state
    /// *handoff*, DESIGN.md §15): a departing node's unsent residuals
    /// are pending gradient mass, so its neighbor inherits them —
    /// coordinate-wise f32 addition of both the residual and the
    /// velocity, preserving total pending mass exactly up to f32
    /// rounding. Both stores must cover the same coordinates.
    pub fn merge_from(&mut self, other: &ResidualStore) {
        assert_eq!(other.res.len(), self.res.len(), "handoff needs equal lengths");
        for i in 0..self.res.len() {
            self.res[i] += other.res[i];
            self.vel[i] += other.vel[i];
        }
    }

    /// Scale all pending state by `factor` (the *drop-and-rescale*
    /// recovery mode, DESIGN.md §15): when a node's store is dropped,
    /// survivors rescale by N/(N−1) so the expected gradient sum is
    /// preserved. Velocity scales too, keeping the momentum recursion
    /// consistent with the rescaled residual.
    pub fn rescale(&mut self, factor: f32) {
        assert!(factor.is_finite() && factor > 0.0);
        for v in self.res.iter_mut() {
            *v *= factor;
        }
        for v in self.vel.iter_mut() {
            *v *= factor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::BitMask;
    use crate::util::prop::forall;

    #[test]
    fn momentum_accumulation_matches_closed_form() {
        let mut s = ResidualStore::new(1, 0.9);
        s.accumulate(&[1.0]);
        s.accumulate(&[1.0]);
        // v1=1, r1=1; v2=0.9+1=1.9, r2=1+1.9=2.9
        assert!((s.pending()[0] - 2.9).abs() < 1e-6);
    }

    #[test]
    fn take_masked_zeroes_selected_only() {
        let mut s = ResidualStore::new(4, 0.0);
        s.accumulate(&[1.0, 2.0, 3.0, 4.0]);
        let mut m = BitMask::zeros(4);
        m.set(1);
        m.set(3);
        let sent = s.take_masked(&m);
        assert_eq!(sent, vec![2.0, 4.0]);
        assert_eq!(s.pending(), &[1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn momentum_factor_masking_clears_velocity() {
        let mut s = ResidualStore::new(2, 0.9);
        s.accumulate(&[1.0, 1.0]);
        let mut m = BitMask::zeros(2);
        m.set(0);
        let _ = s.take_masked(&m);
        s.accumulate(&[0.0, 0.0]);
        // Coord 0's velocity was cleared -> residual stays 0; coord 1 keeps
        // compounding (0.9 * 1.0 added).
        assert_eq!(s.pending()[0], 0.0);
        assert!((s.pending()[1] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn no_gradient_mass_lost_property() {
        // With momentum 0: every accumulated unit is either transmitted or
        // still pending — conservation across arbitrary mask sequences.
        forall("residual conserves gradient mass", 50, |gen| {
            let n = gen.usize_in(1, 100);
            let mut store = ResidualStore::new(n, 0.0);
            let mut transmitted = vec![0.0f64; n];
            let mut injected = vec![0.0f64; n];
            for _ in 0..5 {
                let g = gen.vec_normal(n, 0.0, 1.0);
                for i in 0..n {
                    injected[i] += g[i] as f64;
                }
                store.accumulate(&g);
                let mut mask = BitMask::zeros(n);
                for i in 0..n {
                    if gen.bool() {
                        mask.set(i);
                    }
                }
                let sent = store.take_masked(&mask);
                for (j, i) in mask.iter_set().enumerate() {
                    transmitted[i] += sent[j] as f64;
                }
            }
            for i in 0..n {
                let pending = store.pending()[i] as f64;
                assert!(
                    (injected[i] - transmitted[i] - pending).abs() < 1e-4,
                    "coord {i}: injected {} != sent {} + pending {}",
                    injected[i],
                    transmitted[i],
                    pending
                );
            }
        });
    }

    #[test]
    fn clear_masked_equals_take_masked_discarded() {
        forall("clear_masked == take_masked modulo output", 30, |gen| {
            let n = gen.usize_in(1, 80);
            let g = gen.vec_normal(n, 0.0, 1.0);
            let mut a = ResidualStore::new(n, 0.9);
            let mut b = ResidualStore::new(n, 0.9);
            a.accumulate(&g);
            b.accumulate(&g);
            let mut mask = BitMask::zeros(n);
            for i in 0..n {
                if gen.bool() {
                    mask.set(i);
                }
            }
            let _ = a.take_masked(&mask);
            b.clear_masked(&mask);
            let bits = |s: &ResidualStore| -> Vec<u32> {
                s.pending().iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&a), bits(&b));
            // Velocity agreement is observable through the next step.
            a.accumulate(&g);
            b.accumulate(&g);
            assert_eq!(bits(&a), bits(&b));
        });
    }

    #[test]
    fn take_all_resets() {
        let mut s = ResidualStore::new(3, 0.5);
        s.accumulate(&[1.0, 2.0, 3.0]);
        let all = s.take_all();
        assert_eq!(all, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.residual_norm(), 0.0);
    }

    #[test]
    fn merge_from_adds_residual_and_velocity() {
        let mut a = ResidualStore::new(3, 0.5);
        let mut b = ResidualStore::new(3, 0.5);
        a.accumulate(&[1.0, 2.0, 3.0]);
        b.accumulate(&[0.5, 0.25, 0.125]);
        let total = a.residual_sum() + b.residual_sum();
        a.merge_from(&b);
        assert_eq!(a.pending(), &[1.5, 2.25, 3.125]);
        assert_eq!(a.residual_sum(), total);
        // Velocity merged too: the next accumulate compounds both
        // streams' momentum (0.5 * (1.0 + 0.5) at coord 0).
        a.accumulate(&[0.0, 0.0, 0.0]);
        assert!((a.pending()[0] - (1.5 + 0.75)).abs() < 1e-6);
    }

    #[test]
    fn rescale_is_exact_on_exact_factors() {
        // 1.25 = 5/4 is exactly representable, and powers of two scale
        // without rounding — the drop-and-rescale invariant's
        // documented exactness regime (DESIGN.md §15).
        let mut s = ResidualStore::new(4, 0.0);
        s.accumulate(&[4.0, -8.0, 0.5, 16.0]);
        s.rescale(1.25);
        assert_eq!(s.pending(), &[5.0, -10.0, 0.625, 20.0]);
        assert_eq!(s.residual_sum(), (5.0 - 10.0 + 0.625 + 20.0) as f64);
    }
}
