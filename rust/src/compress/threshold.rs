//! Threshold controllers: fixed (Sec. IV-A sweeps 0.005–0.1) and the
//! Eq. 4 layer-wise adaptive rule.
//!
//! Eq. 4 (paper):
//! ```text
//! thr_layer = alpha_epoch + beta_epoch * (var/mean)   if var/mean > C
//!           = alpha_epoch - beta_epoch * (var/mean)   otherwise
//! ```
//! Rationale (paper Sec. III-D): a large var/mean means the layer's
//! importance distribution is disordered — compress harder (raise thr);
//! a small var/mean with large mean means the layer matters — let more
//! through (lower thr).  `alpha_epoch` is piecewise-constant over epoch
//! intervals; warm-up scaling multiplies on top (see `warmup`).

use super::importance::LayerStats;
use crate::model::ParamLayout;

/// Controller configuration.
#[derive(Debug, Clone, Copy)]
pub struct ThresholdCfg {
    /// Base threshold α (also the fixed threshold when layerwise is off).
    pub alpha: f32,
    /// Dispersion gain β of Eq. 4.
    pub beta: f32,
    /// Dispersion crossover C of Eq. 4.
    pub c: f32,
    /// Epoch schedule for α: multiply by `alpha_decay` every
    /// `alpha_epoch_interval` epochs (paper: "α can be set to a constant
    /// within a certain epoch interval").
    pub alpha_epoch_interval: usize,
    /// Multiplier applied to α at each interval boundary.
    pub alpha_decay: f32,
}

impl Default for ThresholdCfg {
    fn default() -> Self {
        ThresholdCfg {
            alpha: 0.01,
            beta: 0.002,
            c: 1.0,
            alpha_epoch_interval: 20,
            alpha_decay: 1.25, // importance judgement tightens as lr decays
        }
    }
}

impl ThresholdCfg {
    /// α at a given epoch.
    pub fn alpha_at(&self, epoch: usize) -> f32 {
        let k = (epoch / self.alpha_epoch_interval.max(1)) as i32;
        self.alpha * self.alpha_decay.powi(k)
    }
}

/// Threshold policy for one step.
#[derive(Debug, Clone)]
pub enum ThresholdPolicy {
    /// One global threshold for every layer.
    Fixed(f32),
    /// Eq. 4 per-layer thresholds.
    Layerwise(ThresholdCfg),
    /// Variance-gated step rule (`iwp:vargate`, DESIGN.md §12 —
    /// Tsuzuku et al., 1802.06058 adapted to trailing layer stats):
    /// where Eq. 4 adjusts thresholds *linearly* in var/mean, this is a
    /// hard gate — a layer whose trailing var/mean exceeds `gate` is
    /// treated as noisy and compressed `boost`× harder; confident
    /// layers keep the base threshold.
    VarGated {
        /// Base threshold α for confident layers.
        alpha: f32,
        /// Trailing var/mean above which a layer counts as noisy.
        gate: f32,
        /// Threshold multiplier for noisy layers (`>= 1`).
        boost: f32,
    },
}

impl ThresholdPolicy {
    /// Per-layer thresholds for this step. `stats[i]` are the layer-i
    /// importance statistics measured on the *current* pending gradients
    /// (the kernel's stats output aggregated per layer);
    /// `warmup_mult` scales thresholds down during warm-up epochs.
    pub fn layer_thresholds(
        &self,
        layout: &ParamLayout,
        stats: &[LayerStats],
        epoch: usize,
        warmup_mult: f32,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.layer_thresholds_into(layout, stats, epoch, warmup_mult, &mut out);
        out
    }

    /// [`ThresholdPolicy::layer_thresholds`] into a caller-owned buffer
    /// (the per-step engines reuse one buffer instead of allocating).
    pub fn layer_thresholds_into(
        &self,
        layout: &ParamLayout,
        stats: &[LayerStats],
        epoch: usize,
        warmup_mult: f32,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(stats.len(), layout.n_layers());
        out.clear();
        match self {
            ThresholdPolicy::Fixed(thr) => {
                out.resize(layout.n_layers(), (thr * warmup_mult).max(0.0));
            }
            ThresholdPolicy::Layerwise(cfg) => {
                let alpha = cfg.alpha_at(epoch);
                out.extend(stats.iter().map(|s| {
                    let vm = s.var_over_mean() as f32;
                    let thr = if vm > cfg.c {
                        alpha + cfg.beta * vm
                    } else {
                        alpha - cfg.beta * vm
                    };
                    // A threshold can never go negative (that would
                    // transmit everything regardless of importance).
                    (thr * warmup_mult).max(0.0)
                }));
            }
            ThresholdPolicy::VarGated { alpha, gate, boost } => {
                out.extend(stats.iter().map(|s| {
                    let vm = s.var_over_mean() as f32;
                    let thr = if vm > *gate { alpha * boost } else { *alpha };
                    (thr * warmup_mult).max(0.0)
                }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerKind, ParamLayout};

    fn layout2() -> ParamLayout {
        ParamLayout::new(
            "t",
            vec![
                ("a".into(), vec![8], LayerKind::Conv),
                ("b".into(), vec![8], LayerKind::BatchNorm),
            ],
        )
    }

    fn stats_with_vm(vm: f64) -> LayerStats {
        // mean = 1, var = vm  ->  sumsq/n - 1 = vm
        LayerStats {
            sum: 8.0,
            sumsq: 8.0 * (1.0 + vm),
            n_selected: 0.0,
            n: 8.0,
        }
    }

    #[test]
    fn fixed_is_uniform() {
        let p = ThresholdPolicy::Fixed(0.05);
        let thr = p.layer_thresholds(&layout2(), &[stats_with_vm(0.1), stats_with_vm(5.0)], 0, 1.0);
        assert_eq!(thr, vec![0.05, 0.05]);
    }

    #[test]
    fn layerwise_raises_for_disordered_lowers_for_ordered() {
        let cfg = ThresholdCfg {
            alpha: 0.01,
            beta: 0.002,
            c: 1.0,
            ..Default::default()
        };
        let p = ThresholdPolicy::Layerwise(cfg);
        let thr = p.layer_thresholds(
            &layout2(),
            &[stats_with_vm(4.0), stats_with_vm(0.5)],
            0,
            1.0,
        );
        // Layer 0: vm=4 > C -> alpha + beta*4 = 0.018
        assert!((thr[0] - 0.018).abs() < 1e-6, "{}", thr[0]);
        // Layer 1: vm=0.5 <= C -> alpha - beta*0.5 = 0.009
        assert!((thr[1] - 0.009).abs() < 1e-6, "{}", thr[1]);
    }

    #[test]
    fn alpha_epoch_schedule() {
        let cfg = ThresholdCfg::default();
        assert_eq!(cfg.alpha_at(0), cfg.alpha);
        assert_eq!(cfg.alpha_at(19), cfg.alpha);
        assert!((cfg.alpha_at(20) - cfg.alpha * 1.25).abs() < 1e-9);
        assert!((cfg.alpha_at(45) - cfg.alpha * 1.25 * 1.25).abs() < 1e-9);
    }

    #[test]
    fn warmup_scales_down() {
        let p = ThresholdPolicy::Fixed(0.1);
        let thr = p.layer_thresholds(&layout2(), &[stats_with_vm(0.0); 2], 0, 0.25);
        assert_eq!(thr, vec![0.025, 0.025]);
    }

    #[test]
    fn vargated_boosts_noisy_layers_only() {
        let p = ThresholdPolicy::VarGated {
            alpha: 0.01,
            gate: 1.0,
            boost: 4.0,
        };
        let thr = p.layer_thresholds(
            &layout2(),
            &[stats_with_vm(4.0), stats_with_vm(0.5)],
            0,
            1.0,
        );
        // Layer 0: vm=4 > gate -> alpha * boost = 0.04.
        assert!((thr[0] - 0.04).abs() < 1e-7, "{}", thr[0]);
        // Layer 1: vm=0.5 <= gate -> base alpha.
        assert!((thr[1] - 0.01).abs() < 1e-7, "{}", thr[1]);
        // Warm-up scaling multiplies on top, like every policy.
        let thr = p.layer_thresholds(&layout2(), &[stats_with_vm(4.0); 2], 0, 0.5);
        assert!((thr[0] - 0.02).abs() < 1e-7);
    }

    #[test]
    fn never_negative() {
        let cfg = ThresholdCfg {
            alpha: 0.001,
            beta: 1.0,
            c: 10.0, // vm below C -> alpha - beta*vm would go negative
            ..Default::default()
        };
        let p = ThresholdPolicy::Layerwise(cfg);
        let thr = p.layer_thresholds(&layout2(), &[stats_with_vm(5.0); 2], 0, 1.0);
        assert!(thr.iter().all(|&t| t >= 0.0));
    }
}
