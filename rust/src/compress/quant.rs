//! Parametric low-precision payload stage (`+q:<bits>`, DESIGN.md §17).
//!
//! Generalizes the one-off `+tern` stage (compress/terngrad.rs) into a
//! family of wire precisions for the compacted shared-mask payload:
//!
//! - `+q:16b` — bf16: truncate-with-round-to-nearest-even of the f32 bit
//!   pattern. No scales, no RNG.
//! - `+q:16`  — IEEE binary16 (f16), round-to-nearest-even, gradual
//!   underflow. No scales, no RNG.
//! - `+q:8` / `+q:4` / `+q:2` — k-bit block quantization: the payload is
//!   cut into fixed-width blocks of [`QUANT_BLOCK`] elements, each block
//!   carries one f32 scale `s = max|v|`, and every element is rounded
//!   stochastically onto the signed grid `{-L..L}·s/L` where
//!   `L = 2^(k-1) - 1` levels (q8: 127, q4: 7, q2: 1). The rounding is
//!   unbiased: `q = floor(t) + Bernoulli(frac(t))` with `t = |v|/s·L`
//!   satisfies `E[q·s/L] = |v|` exactly (up to f32 rounding of `t`),
//!   consuming exactly one `Rng::uniform()` draw per element of a
//!   non-zero block — the same stream discipline as `TernBlob`.
//!
//! `+q:2` is *definitionally* `+tern`: at `L = 1` the grid is `{-s,0,s}`,
//! `floor(t) = 0` for `|v| < s` so the Bernoulli test degenerates to
//! TernGrad's `u < |v|/s`, and the 2-bit code map below reproduces
//! `TernBlob`'s `CODE_ZERO/CODE_POS/CODE_NEG` packing byte for byte
//! (pinned by `q2_single_block_matches_tern_blob` here and by
//! tests/quant_equivalence.rs at the engine level). The engine therefore
//! routes `+q:2` through the existing `TernBlob` path; `QBlob` carries
//! the other widths.
//!
//! Code map (k-bit widths): `0` = zero, `1..=L` = `+q`, `L+1..=2L` = `-q`
//! (code `L+q` encodes magnitude `q`). Codes pack little-end-first,
//! `8/k` per byte, exactly like `TernBlob` at k = 2.
//!
//! Like `TernBlob`, quantized blobs are NOT closed under addition
//! (grids differ per block), so they spread whole around the ring and
//! every rank decodes-and-sums all `n` blobs (DESIGN.md §10, §17). The
//! wire layout lives in net/wire/codec.rs (`encode_q_blob`).
//!
//! Kernel shape: the quantize path is written in the two-phase blocked
//! form of compress/fuse.rs — phase 1 computes `floor`/`frac` for a
//! [`fuse::BLOCK`]-wide run of elements with no cross-element
//! dependencies (autovectorizes on stable Rust: `abs`, `div`, `mul`,
//! `cvttps2dq`), phase 2 walks the run scalar for the sequential RNG
//! draws and bit packing. See DESIGN.md §17 and `benches/bench_compress.rs`
//! for the measured win.

use crate::compress::terngrad::TernBlob;
use crate::util::rng::Rng;

/// Elements per scale block for k-bit widths. One f32 scale per block is
/// 4/QUANT_BLOCK bytes of overhead per element (0.4% at q8) while keeping
/// the grid local enough that one outlier cannot flatten a whole layer.
pub const QUANT_BLOCK: usize = 1024;

/// Serialized `QBlob` overhead: width tag (u8) + block (u32) + len (u32).
/// Deliberately equal to sparse::HEADER_BYTES so the §17 closed forms
/// compare like with like.
pub const QBLOB_HEADER_BYTES: u64 = 9;

/// Inner run width for the two-phase quantize kernel; matches
/// compress/fuse.rs BLOCK so both kernels vectorize the same way.
const BLOCK: usize = 64;

/// Wire precision for the `+q:<bits>` stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantWidth {
    /// bfloat16: f32 with the low 16 mantissa bits rounded away.
    Bf16,
    /// IEEE binary16.
    F16,
    /// 8-bit block quantization, 127 levels per sign.
    Q8,
    /// 4-bit block quantization, 7 levels per sign.
    Q4,
    /// 2-bit block quantization ≡ TernGrad ternary (`+tern`).
    Q2,
}

impl QuantWidth {
    /// Every width, widest to narrowest (sweep/doc order).
    pub const ALL: [QuantWidth; 5] = [
        QuantWidth::Bf16,
        QuantWidth::F16,
        QuantWidth::Q8,
        QuantWidth::Q4,
        QuantWidth::Q2,
    ];

    /// Grammar token as written after `+q:` in a method spec.
    pub fn token(self) -> &'static str {
        match self {
            QuantWidth::Bf16 => "16b",
            QuantWidth::F16 => "16",
            QuantWidth::Q8 => "8",
            QuantWidth::Q4 => "4",
            QuantWidth::Q2 => "2",
        }
    }

    /// Short name used by tuner strategies and bench row ids.
    pub fn name(self) -> &'static str {
        match self {
            QuantWidth::Bf16 => "bf16",
            QuantWidth::F16 => "f16",
            QuantWidth::Q8 => "q8",
            QuantWidth::Q4 => "q4",
            QuantWidth::Q2 => "q2",
        }
    }

    /// Parse the `<bits>` token of a `+q:<bits>` stage.
    pub fn parse(tok: &str) -> anyhow::Result<Self> {
        Ok(match tok {
            "16b" => QuantWidth::Bf16,
            "16" => QuantWidth::F16,
            "8" => QuantWidth::Q8,
            "4" => QuantWidth::Q4,
            "2" => QuantWidth::Q2,
            other => anyhow::bail!(
                "unknown quantization width `{other}` (expected one of: 16b | 16 | 8 | 4 | 2)"
            ),
        })
    }

    /// Width tag byte of the `qblob` wire layout (net/wire/codec.rs).
    pub fn wire_tag(self) -> u8 {
        match self {
            QuantWidth::Bf16 => 1,
            QuantWidth::F16 => 2,
            QuantWidth::Q8 => 3,
            QuantWidth::Q4 => 4,
            QuantWidth::Q2 => 5,
        }
    }

    /// Decode a `qblob` width tag byte (total: `None` on garbage).
    pub fn from_wire_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            1 => QuantWidth::Bf16,
            2 => QuantWidth::F16,
            3 => QuantWidth::Q8,
            4 => QuantWidth::Q4,
            5 => QuantWidth::Q2,
            _ => return None,
        })
    }

    /// Bits per transmitted code.
    pub fn bits(self) -> u32 {
        match self {
            QuantWidth::Bf16 | QuantWidth::F16 => 16,
            QuantWidth::Q8 => 8,
            QuantWidth::Q4 => 4,
            QuantWidth::Q2 => 2,
        }
    }

    /// Float widths carry raw half-precision bit patterns: no scales, no
    /// stochastic rounding, no RNG draws.
    pub fn is_float(self) -> bool {
        matches!(self, QuantWidth::Bf16 | QuantWidth::F16)
    }

    /// Quantization levels per sign for k-bit widths: `L = 2^(k-1) - 1`.
    /// Float widths have no grid; callers must gate on [`is_float`].
    ///
    /// [`is_float`]: QuantWidth::is_float
    pub fn levels(self) -> u32 {
        debug_assert!(!self.is_float(), "float widths have no level grid");
        (1u32 << (self.bits() - 1)) - 1
    }

    /// Packed code bytes for `nnz` elements.
    pub fn code_bytes(self, nnz: usize) -> usize {
        if self.is_float() {
            2 * nnz
        } else {
            let per = (8 / self.bits()) as usize;
            nnz.div_ceil(per)
        }
    }

    /// Scale slots for `nnz` elements at the canonical [`QUANT_BLOCK`].
    pub fn scale_slots(self, nnz: usize) -> usize {
        if self.is_float() {
            0
        } else {
            nnz.div_ceil(QUANT_BLOCK)
        }
    }
}

impl std::fmt::Display for QuantWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A quantized whole-payload blob: the `+q` analogue of [`TernBlob`].
///
/// For k-bit widths `scales[b]` is the absmax of elements
/// `[b·block, (b+1)·block)` and `codes` packs `8/k` codes per byte,
/// little-end-first. For float widths `scales` is empty, `block` is 0
/// and `codes` holds `len` little-endian u16 bit patterns.
#[derive(Debug, Clone, PartialEq)]
pub struct QBlob {
    pub width: QuantWidth,
    /// Number of payload elements.
    pub len: usize,
    /// Elements per scale block (0 for float widths).
    pub block: usize,
    pub scales: Vec<f32>,
    pub codes: Vec<u8>,
}

impl QBlob {
    /// Encode at the canonical [`QUANT_BLOCK`] scale-block width.
    pub fn encode(values: &[f32], width: QuantWidth, rng: &mut Rng) -> Self {
        Self::encode_blocked(values, width, QUANT_BLOCK, rng)
    }

    /// Encode with an explicit scale-block width (k-bit widths only use
    /// it; float widths ignore it). `block = len` reproduces the
    /// whole-payload single-scale regime of [`TernBlob`].
    pub fn encode_blocked(values: &[f32], width: QuantWidth, block: usize, rng: &mut Rng) -> Self {
        if width.is_float() {
            let mut codes = Vec::with_capacity(2 * values.len());
            match width {
                QuantWidth::Bf16 => {
                    for &v in values {
                        codes.extend_from_slice(&f32_to_bf16(v).to_le_bytes());
                    }
                }
                _ => {
                    for &v in values {
                        codes.extend_from_slice(&f32_to_f16(v).to_le_bytes());
                    }
                }
            }
            return QBlob { width, len: values.len(), block: 0, scales: Vec::new(), codes };
        }

        assert!(block > 0, "k-bit quantization needs a positive scale block");
        let bits = width.bits() as usize;
        let per = 8 / bits;
        let levels = width.levels() as f32;
        let mut codes = vec![0u8; values.len().div_ceil(per)];
        let mut scales = Vec::with_capacity(values.len().div_ceil(block));

        // Phase-1 staging for one inner run (two-phase fuse.rs idiom).
        let mut whole = [0u32; BLOCK];
        let mut frac = [0f32; BLOCK];

        for (b, chunk) in values.chunks(block).enumerate() {
            // Absmax is associative, so the blocked reduce below matches
            // TernBlob's sequential fold bit for bit (finite payloads).
            let scale = chunk.iter().fold(0f32, |m, &v| m.max(v.abs()));
            scales.push(scale);
            if scale == 0.0 {
                // All-zero block: codes stay 0 and — like TernBlob's
                // zero-scale guard — no RNG draws are consumed.
                continue;
            }
            let base = b * block;
            let mut off = 0;
            while off < chunk.len() {
                let run = (chunk.len() - off).min(BLOCK);
                // Phase 1: element-independent arithmetic over the run.
                // `t ∈ [0, L]` because `|v|/s ≤ 1` exactly in f32 and
                // multiplying by L is monotone; truncation equals floor
                // for non-negative t.
                for k in 0..run {
                    let t = chunk[off + k].abs() / scale * levels;
                    let fl = t as u32;
                    whole[k] = fl;
                    frac[k] = t - fl as f32;
                }
                // Phase 2: sequential RNG + sign + bit packing. One
                // uniform per element, in element order — the stream
                // contract shared with TernBlob.
                for k in 0..run {
                    let mut q = whole[k];
                    if rng.uniform() < frac[k] {
                        q += 1;
                    }
                    if q == 0 {
                        continue;
                    }
                    let code = if chunk[off + k] >= 0.0 { q } else { q + levels as u32 };
                    let i = base + off + k;
                    codes[i / per] |= (code as u8) << ((i % per) * bits);
                }
                off += run;
            }
        }
        QBlob { width, len: values.len(), block, scales, codes }
    }

    /// Decode and add every element into `acc` (`acc[i] += q_i`).
    /// Total: any byte pattern decodes (codes above `2L` clamp to the
    /// negative end of the grid rather than panicking).
    pub fn add_decoded_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.len, "quant blob length mismatch");
        if self.width.is_float() {
            let from = match self.width {
                QuantWidth::Bf16 => bf16_to_f32,
                _ => f16_to_f32,
            };
            for (i, a) in acc.iter_mut().enumerate() {
                let h = u16::from_le_bytes([self.codes[2 * i], self.codes[2 * i + 1]]);
                *a += from(h);
            }
            return;
        }
        let bits = self.width.bits() as usize;
        let per = 8 / bits;
        let mask = (1u8 << bits) - 1;
        let levels = self.width.levels();
        for (b, chunk) in acc.chunks_mut(self.block).enumerate() {
            let scale = self.scales[b];
            if scale == 0.0 {
                continue;
            }
            // One divide per block; at q2 `unit = s/1.0 = s` exactly, so
            // the decoded grid matches TernBlob's ±scale bit for bit.
            let unit = scale / levels as f32;
            let base = b * self.block;
            for (k, a) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let code = (self.codes[i / per] >> ((i % per) * bits)) & mask;
                if code == 0 {
                    continue;
                }
                let code = code as u32;
                if code <= levels {
                    *a += code as f32 * unit;
                } else {
                    *a -= (code - levels).min(levels) as f32 * unit;
                }
            }
        }
    }

    /// Wire size of this blob as serialized by net/wire/codec.rs.
    pub fn wire_bytes(&self) -> u64 {
        QBLOB_HEADER_BYTES + 4 * self.scales.len() as u64 + self.codes.len() as u64
    }

    /// Closed-form wire size for `nnz` surviving coordinates at the
    /// canonical [`QUANT_BLOCK`]; feeds `CostModel::masked_q_*`
    /// (net/cost.rs). The q2 form delegates to [`TernBlob`] because the
    /// engine ships q2 payloads on the tern path.
    pub fn wire_bytes_for(nnz: usize, width: QuantWidth) -> u64 {
        if width == QuantWidth::Q2 {
            return TernBlob::wire_bytes_for(nnz);
        }
        QBLOB_HEADER_BYTES
            + 4 * width.scale_slots(nnz) as u64
            + width.code_bytes(nnz) as u64
    }
}

/// f32 → bf16 with round-to-nearest-even; NaN keeps a quiet payload.
pub fn f32_to_bf16(v: f32) -> u16 {
    let b = v.to_bits();
    if v.is_nan() {
        return ((b >> 16) as u16) | 0x0040;
    }
    // Cannot overflow u32: the largest non-NaN pattern is 0xFF80_0000.
    ((b + 0x7FFF + ((b >> 16) & 1)) >> 16) as u16
}

/// bf16 → f32 (exact).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 → IEEE binary16 with round-to-nearest-even and gradual underflow.
pub fn f32_to_f16(v: f32) -> u16 {
    let b = v.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xFF) as i32;
    let man = b & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN; force a nonzero mantissa with the quiet bit for NaN.
        return sign | 0x7C00 | if man != 0 { 0x0200 | ((man >> 13) as u16 & 0x03FF) } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows even the smallest subnormal → ±0
        }
        // Subnormal: shift the (implicit-bit-restored) mantissa into
        // place, rounding to nearest even.
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let rounded = man + (1 << (shift - 1)) - 1 + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // Normal: round the 23-bit mantissa to 10 bits; a carry out of the
    // mantissa bumps the exponent (possibly to inf) arithmetically.
    let rounded = man + 0x0FFF + ((man >> 13) & 1);
    sign | (((e as u32) << 10) + (rounded >> 13)) as u16
}

/// IEEE binary16 → f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    let bits = match exp {
        0 => {
            if man == 0 {
                sign
            } else {
                // Subnormal: renormalize into an f32 exponent.
                let mut e32 = 127 - 15 + 1;
                let mut m = man;
                while m & 0x0400 == 0 {
                    m <<= 1;
                    e32 -= 1;
                }
                sign | ((e32 as u32) << 23) | ((m & 0x03FF) << 13)
            }
        }
        0x1F => sign | 0x7F80_0000 | (man << 13),
        _ => sign | ((exp as u32 + 127 - 15) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_with(0.0, 0.3)).collect()
    }

    #[test]
    fn q2_single_block_matches_tern_blob_byte_for_byte() {
        let mut rng = Rng::new(0x51C2);
        let vals = payload(257, &mut rng);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let q = QBlob::encode_blocked(&vals, QuantWidth::Q2, vals.len(), &mut r1);
        let t = TernBlob::encode(&vals, &mut r2);
        assert_eq!(q.codes, t.codes, "identical packing and draws at L = 1");
        assert_eq!(q.scales, vec![t.scale]);
        // Identical RNG stream consumption.
        assert_eq!(r1.uniform(), r2.uniform());
        // Identical decode.
        let mut a = vec![0f32; vals.len()];
        let mut b = vec![0f32; vals.len()];
        q.add_decoded_into(&mut a);
        t.add_decoded_into(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn stochastic_rounding_is_unbiased_per_width() {
        // E[decode(encode(x))] = x for the k-bit widths; float widths
        // are deterministic nearest-even so the error is bounded by half
        // a ulp of the target format instead.
        for width in [QuantWidth::Q8, QuantWidth::Q4, QuantWidth::Q2] {
            let mut rng = Rng::new(0xB1A5 ^ width.bits() as u64);
            let vals = payload(64, &mut rng);
            let trials = 4000;
            let mut mean = vec![0f64; vals.len()];
            for t in 0..trials {
                let mut enc_rng = Rng::new(0xD00D + t);
                let q = QBlob::encode(&vals, width, &mut enc_rng);
                let mut dec = vec![0f32; vals.len()];
                q.add_decoded_into(&mut dec);
                for (m, d) in mean.iter_mut().zip(&dec) {
                    *m += *d as f64 / trials as f64;
                }
            }
            let unit = vals.iter().fold(0f32, |m, &v| m.max(v.abs())) / width.levels() as f32;
            // Bernoulli std per trial ≤ unit/2; 5 sigma over `trials`.
            let tol = 5.0 * (unit as f64) / 2.0 / (trials as f64).sqrt();
            for (m, &v) in mean.iter().zip(&vals) {
                assert!(
                    (m - v as f64).abs() < tol,
                    "{width}: E[q(x)] = {m} vs x = {v} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn float_widths_round_to_nearest_and_skip_rng() {
        let vals = [1.0f32, -2.5, 0.1, 3.0e-5, -7.25e4, 0.0];
        for width in [QuantWidth::Bf16, QuantWidth::F16] {
            let mut r = Rng::new(3);
            let mut before = r.clone();
            let q = QBlob::encode(&vals, width, &mut r);
            assert_eq!(r.next_u64(), before.next_u64(), "float widths must not touch the RNG");
            assert!(q.scales.is_empty());
            let mut dec = vec![0f32; vals.len()];
            q.add_decoded_into(&mut dec);
            for (&d, &v) in dec.iter().zip(&vals) {
                let rel = if v == 0.0 { d.abs() } else { ((d - v) / v).abs() };
                // Half-ulp of an 8-bit (bf16) mantissa is the looser bound.
                assert!(rel <= 1.0 / 256.0, "{width}: {d} vs {v}");
            }
        }
        // Exactly representable values roundtrip bit-for-bit.
        assert_eq!(bf16_to_f32(f32_to_bf16(1.5)), 1.5);
        assert_eq!(f16_to_f32(f32_to_f16(-0.375)), -0.375);
        // f16 gradual underflow: 2^-24 is the smallest subnormal.
        assert_eq!(f16_to_f32(f32_to_f16(2.0f32.powi(-24))), 2.0f32.powi(-24));
        assert_eq!(f16_to_f32(f32_to_f16(2.0f32.powi(-26))), 0.0);
        // Infinities and NaN survive both conversions.
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn max_magnitude_always_transmits_at_every_k_bit_width() {
        // |v| == s has frac 0 after the floor split, so the max lands on
        // the top grid level deterministically (TernGrad's guarantee,
        // generalized). Decoding `L·(s/L)` reintroduces at most two f32
        // roundings, so compare with a couple-ulp relative tolerance
        // (exactly zero at q2 where the grid step is `s` itself).
        for width in [QuantWidth::Q8, QuantWidth::Q4, QuantWidth::Q2] {
            for seed in 0..32 {
                let mut rng = Rng::new(seed);
                let vals = [0.01f32, -0.9, 0.02, 0.5];
                let q = QBlob::encode(&vals, width, &mut rng);
                let mut dec = vec![0f32; vals.len()];
                q.add_decoded_into(&mut dec);
                assert!(
                    ((dec[1] + 0.9) / 0.9).abs() <= 1e-6,
                    "{width}: absmax must hit the top level ({})",
                    dec[1]
                );
                if width == QuantWidth::Q2 {
                    assert_eq!(dec[1], -0.9);
                }
            }
        }
    }

    #[test]
    fn per_block_scales_localize_outliers() {
        // One huge element in block 0 must not flatten block 1's grid.
        let mut vals = vec![0.001f32; 2 * QUANT_BLOCK];
        vals[0] = 1000.0;
        let mut rng = Rng::new(11);
        let q = QBlob::encode(&vals, QuantWidth::Q8, &mut rng);
        assert_eq!(q.scales.len(), 2);
        assert_eq!(q.scales[0], 1000.0);
        assert_eq!(q.scales[1], 0.001);
        let mut dec = vec![0f32; vals.len()];
        q.add_decoded_into(&mut dec);
        // Block 1 decodes its small values on its own fine grid (the
        // shared-scale alternative would round them all to zero).
        assert!(((dec[QUANT_BLOCK + 1] - 0.001) / 0.001).abs() <= 1e-6);
    }

    #[test]
    fn wire_bytes_closed_forms() {
        // Float widths: 9 + 2 per element, no scales.
        assert_eq!(QBlob::wire_bytes_for(1000, QuantWidth::Bf16), 9 + 2000);
        assert_eq!(QBlob::wire_bytes_for(1000, QuantWidth::F16), 9 + 2000);
        // k-bit: 9 + 4·ceil(n/1024) + ceil(n·k/8).
        assert_eq!(QBlob::wire_bytes_for(1000, QuantWidth::Q8), 9 + 4 + 1000);
        assert_eq!(QBlob::wire_bytes_for(1025, QuantWidth::Q4), 9 + 8 + 513);
        // q2 delegates to TernBlob (whole-payload single scale).
        assert_eq!(
            QBlob::wire_bytes_for(1025, QuantWidth::Q2),
            TernBlob::wire_bytes_for(1025)
        );
        // Instance sizes agree with the closed form at the canonical block.
        let mut rng = Rng::new(5);
        let vals = payload(1500, &mut rng);
        for width in [QuantWidth::Bf16, QuantWidth::F16, QuantWidth::Q8, QuantWidth::Q4] {
            let q = QBlob::encode(&vals, width, &mut rng);
            assert_eq!(q.wire_bytes(), QBlob::wire_bytes_for(vals.len(), width), "{width}");
        }
    }

    #[test]
    fn zero_payload_and_zero_block_are_total() {
        let mut rng = Rng::new(9);
        for width in QuantWidth::ALL {
            let q = QBlob::encode(&[], width, &mut rng);
            assert_eq!(q.len, 0);
            assert!(q.codes.is_empty());
            q.add_decoded_into(&mut []);
        }
        // An all-zero block encodes to zero codes and zero scale, and
        // consumes no RNG draws.
        let mut r = Rng::new(4);
        let mut before = r.clone();
        let q = QBlob::encode(&[0.0; 10], QuantWidth::Q4, &mut r);
        assert_eq!(r.next_u64(), before.next_u64());
        assert_eq!(q.scales, vec![0.0]);
        let mut dec = vec![1.0f32; 10];
        q.add_decoded_into(&mut dec);
        assert_eq!(dec, vec![1.0; 10]);
    }

    #[test]
    fn decode_is_total_on_arbitrary_codes() {
        // Any byte soup decodes without panicking (wire-facing contract).
        let blob = QBlob {
            width: QuantWidth::Q4,
            len: 16,
            block: QUANT_BLOCK,
            scales: vec![2.0],
            codes: (0..8).map(|i| (i * 37 + 255) as u8).collect(),
        };
        let mut dec = vec![0f32; 16];
        blob.add_decoded_into(&mut dec);
        for d in dec {
            assert!(d.abs() <= 2.0 + 1e-6);
        }
    }

    #[test]
    fn width_tokens_roundtrip() {
        for w in QuantWidth::ALL {
            assert_eq!(QuantWidth::parse(w.token()).unwrap(), w);
            assert_eq!(QuantWidth::from_wire_tag(w.wire_tag()), Some(w));
        }
        assert_eq!(QuantWidth::from_wire_tag(0), None);
        assert_eq!(QuantWidth::from_wire_tag(6), None);
        assert!(QuantWidth::parse("3").is_err());
        assert!(QuantWidth::parse("32").is_err());
        assert_eq!(QuantWidth::Q8.levels(), 127);
        assert_eq!(QuantWidth::Q4.levels(), 7);
        assert_eq!(QuantWidth::Q2.levels(), 1);
    }
}
