//! Local gradient clipping — applied per node *before* residual
//! accumulation (the paper: "we has implemented warm-up training and
//! local gradient clip", inherited from DGC where per-node clipping by
//! N^{-1/2}-scaled global norm keeps the summed update bounded).

/// Clip `grad` in place to `max_norm` (global L2). Returns the pre-clip
/// norm. No-op if the norm is already within bounds or max_norm <= 0.
pub fn clip_by_global_norm(grad: &mut [f32], max_norm: f32) -> f64 {
    let norm = grad
        .iter()
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt();
    if max_norm > 0.0 && norm > max_norm as f64 {
        let scale = (max_norm as f64 / norm) as f32;
        grad.iter_mut().for_each(|v| *v *= scale);
    }
    norm
}

/// DGC's per-node scaling: each of N nodes clips to `global / sqrt(N)` so
/// the *sum* stays within `global`.
pub fn per_node_max_norm(global_max: f32, n_nodes: usize) -> f32 {
    global_max / (n_nodes as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clips_to_max_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_by_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-9);
        let post = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((post - 1.0).abs() < 1e-6);
        // Direction preserved.
        assert!((g[0] / g[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn within_bounds_untouched() {
        let mut g = vec![0.3f32, 0.4];
        clip_by_global_norm(&mut g, 1.0);
        assert_eq!(g, vec![0.3, 0.4]);
    }

    #[test]
    fn zero_max_disables() {
        let mut g = vec![100.0f32];
        clip_by_global_norm(&mut g, 0.0);
        assert_eq!(g, vec![100.0]);
    }

    #[test]
    fn per_node_scaling() {
        assert!((per_node_max_norm(4.0, 16) - 1.0).abs() < 1e-6);
    }
}
