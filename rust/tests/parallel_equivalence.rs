//! The parallel executor's contract (DESIGN.md §4): for every schedule
//! and engine, `parallelism = W` produces **bit-identical** results to
//! the sequential oracle (`parallelism = 1`) — same `ReduceReport`
//! (bytes, virtual seconds, density per hop), same reduced values, same
//! RNG evolution. No tolerance comparisons here: equality is exact.

use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, RingNet};
use ringiwp::ring::{self, Arena, Executor, ReduceReport};
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::prop::forall;
use ringiwp::util::rng::Rng;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::gigabit_ethernet(), 0.05)
}

fn assert_reports_identical(seq: &ReduceReport, par: &ReduceReport, ctx: &str) {
    assert_eq!(seq.bytes_per_node, par.bytes_per_node, "{ctx}: bytes");
    assert_eq!(
        seq.seconds.to_bits(),
        par.seconds.to_bits(),
        "{ctx}: seconds {} vs {}",
        seq.seconds,
        par.seconds
    );
    let db = |r: &ReduceReport| -> Vec<u64> {
        r.density_per_hop.iter().map(|d| d.to_bits()).collect()
    };
    assert_eq!(db(seq), db(par), "{ctx}: density_per_hop");
}

fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> SparseVec {
    let mut dense = vec![0.0f32; len];
    for v in dense.iter_mut() {
        if (rng.uniform() as f64) < density {
            *v = rng.normal();
        }
    }
    SparseVec::from_dense(&dense)
}

const RING_SIZES: [usize; 3] = [4, 8, 96];
const WORKERS: [usize; 3] = [2, 4, 8];

#[test]
fn dense_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 6000;
        let mut rng = Rng::new(7 + n as u64);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut net_seq = net(n);
        let mut bufs_seq = base.clone();
        let rep_seq = ring::dense::allreduce(&mut net_seq, &mut bufs_seq);
        for w in WORKERS {
            let mut net_par = net(n);
            let mut bufs_par = base.clone();
            let rep_par =
                ring::dense::allreduce_exec(&mut net_par, &mut bufs_par, &Executor::new(w));
            assert_reports_identical(&rep_seq, &rep_par, &format!("dense n={n} w={w}"));
            for (s, p) in bufs_seq.iter().zip(&bufs_par) {
                let sb: Vec<u32> = s.iter().map(|v| v.to_bits()).collect();
                let pb: Vec<u32> = p.iter().map(|v| v.to_bits()).collect();
                assert_eq!(sb, pb, "dense n={n} w={w}: reduced values");
            }
            assert_eq!(net_seq.clock().to_bits(), net_par.clock().to_bits());
        }
    }
}

#[test]
fn sparse_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 4000;
        let mut rng = Rng::new(11 + n as u64);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.02))
            .collect();
        let mut net_seq = net(n);
        let (sum_seq, rep_seq) = ring::sparse::allreduce(&mut net_seq, &inputs);
        for w in WORKERS {
            let mut net_par = net(n);
            let (sum_par, rep_par) =
                ring::sparse::allreduce_exec(&mut net_par, &inputs, &Executor::new(w));
            assert_reports_identical(&rep_seq, &rep_par, &format!("sparse n={n} w={w}"));
            let sb: Vec<u32> = sum_seq.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = sum_par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "sparse n={n} w={w}: reduced values");
        }
    }
}

#[test]
fn sparse_support_path_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 50_000;
        let mut rng = Rng::new(13 + n as u64);
        let supports: Vec<BitMask> = (0..n)
            .map(|_| {
                let mut m = BitMask::zeros(len);
                for _ in 0..500 {
                    m.set(rng.below(len));
                }
                m
            })
            .collect();
        let mut net_seq = net(n);
        let rep_seq = ring::sparse::allreduce_support(&mut net_seq, &supports);
        for w in WORKERS {
            let mut net_par = net(n);
            let rep_par = ring::sparse::allreduce_support_exec(
                &mut net_par,
                &supports,
                &Executor::new(w),
            );
            assert_reports_identical(&rep_seq, &rep_par, &format!("support n={n} w={w}"));
        }
    }
}

#[test]
fn masked_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 20_000;
        let mut rng = Rng::new(17 + n as u64);
        let mut mask_a = BitMask::zeros(len);
        let mut mask_b = BitMask::zeros(len);
        for _ in 0..300 {
            mask_a.set(rng.below(len));
            mask_b.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let mut net_seq = net(n);
        let (shared_seq, summed_seq, rep_seq) =
            ring::masked::allreduce(&mut net_seq, &[&mask_a, &mask_b], &refs);
        for w in WORKERS {
            let mut net_par = net(n);
            let (shared_par, summed_par, rep_par) = ring::masked::allreduce_exec(
                &mut net_par,
                &[&mask_a, &mask_b],
                &refs,
                &Executor::new(w),
            );
            assert_eq!(shared_seq, shared_par, "masked n={n} w={w}: shared mask");
            assert_reports_identical(&rep_seq, &rep_par, &format!("masked n={n} w={w}"));
            let sb: Vec<u32> = summed_seq.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = summed_par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "masked n={n} w={w}: summed values");
        }
    }
}

/// Satellite property: across random seeds/shapes, every schedule's
/// parallel report equals the sequential one exactly.
#[test]
fn reduce_report_equality_property_across_seeds() {
    forall("parallel ReduceReport == sequential", 20, |g| {
        let n = g.usize_in(2, 10);
        let len = g.usize_in(n.max(8), 600);
        let workers = g.choice(&[2usize, 3, 5, 8]);
        let exec = Executor::new(workers);
        let seed = g.rng().next_u64();
        let mut rng = Rng::new(seed);

        // Dense.
        let base: Vec<Vec<f32>> = (0..n).map(|_| {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        }).collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (mut ba, mut bb) = (base.clone(), base);
        let ra = ring::dense::allreduce(&mut na, &mut ba);
        let rb = ring::dense::allreduce_exec(&mut nb, &mut bb, &exec);
        assert_reports_identical(&ra, &rb, &format!("prop dense seed={seed}"));

        // Sparse.
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.1))
            .collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (va, ra) = ring::sparse::allreduce(&mut na, &inputs);
        let (vb, rb) = ring::sparse::allreduce_exec(&mut nb, &inputs, &exec);
        assert_reports_identical(&ra, &rb, &format!("prop sparse seed={seed}"));
        assert_eq!(
            va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        // Masked.
        let mut mask = BitMask::zeros(len);
        for _ in 0..len / 4 {
            mask.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (sa, va, ra) = ring::masked::allreduce(&mut na, &[&mask], &refs);
        let (sb, vb, rb) = ring::masked::allreduce_exec(&mut nb, &[&mask], &refs, &exec);
        assert_eq!(sa, sb);
        assert_reports_identical(&ra, &rb, &format!("prop masked seed={seed}"));
        assert_eq!(
            va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    });
}

// ---- engine-level equivalence -----------------------------------------

fn sim_layout() -> ParamLayout {
    ParamLayout::new(
        "sim_eq",
        vec![
            ("conv1".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn1".into(), vec![64], LayerKind::BatchNorm),
            ("conv2".into(), vec![64, 32, 3, 3], LayerKind::Conv),
            ("fc".into(), vec![512, 10], LayerKind::Fc),
            ("bias".into(), vec![10], LayerKind::Bias),
        ],
    )
}

fn run_engine(method: Method, nodes: usize, parallelism: usize) -> (Vec<(u64, u64, u64)>, f64) {
    let cfg = SimCfg {
        nodes,
        method: method.spec(),
        parallelism,
        link: LinkSpec::gigabit_ethernet(),
        seed: 23,
        ..Default::default()
    };
    let mut engine = SimEngine::new(sim_layout(), cfg);
    let mut reports = Vec::new();
    for s in 0..3 {
        let r = engine.step(s);
        reports.push((r.wire_bytes_per_node, r.density.to_bits(), r.seconds.to_bits()));
    }
    (reports, engine.account.ratio())
}

// ---- golden pre-refactor references (PR 2 arena contract) -------------
//
// Verbatim copies of the schedules as they stood BEFORE the staging-
// arena refactor (sequential path, per-hop `Vec` allocations and all).
// They are the checked-in golden oracle: the arena paths must reproduce
// their `ReduceReport`s and reduced values bit-for-bit, so "zero-alloc"
// can never silently become "slightly different numbers".
mod golden {
    use ringiwp::net::RingNet;
    use ringiwp::ring::{chunk_ranges, chunk_ranges_aligned, ReduceReport};
    use ringiwp::sparse::{wire_bytes, BitMask, SparseVec, WireFormat};

    fn snapshot(net: &RingNet) -> Vec<u64> {
        (0..net.n_nodes()).map(|i| net.node_tx_bytes(i)).collect()
    }

    fn delta(net: &RingNet, before: &[u64]) -> Vec<u64> {
        (0..net.n_nodes())
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect()
    }

    pub fn dense(net: &mut RingNet, bufs: &mut [Vec<f32>]) -> ReduceReport {
        let n = net.n_nodes();
        assert_eq!(bufs.len(), n);
        let len = bufs[0].len();
        if len == 0 {
            return ReduceReport {
                bytes_per_node: vec![0; n],
                ..Default::default()
            };
        }
        let chunks = chunk_ranges(len, n);
        let before = snapshot(net);
        let t0 = net.clock();
        for r in 0..n - 1 {
            let sends: Vec<u64> = (0..n)
                .map(|i| (chunks[(i + n - r) % n].len() * 4) as u64)
                .collect();
            net.round(&sends);
            let staged: Vec<Vec<f32>> = (0..n)
                .map(|i| bufs[i][chunks[(i + n - r) % n].clone()].to_vec())
                .collect();
            for dst in 0..n {
                let src = (dst + n - 1) % n;
                let c = (src + n - r) % n;
                for (k, idx) in chunks[c].clone().enumerate() {
                    bufs[dst][idx] += staged[src][k];
                }
            }
        }
        for r in 0..n - 1 {
            let sends: Vec<u64> = (0..n)
                .map(|i| (chunks[(i + 1 + n - r) % n].len() * 4) as u64)
                .collect();
            net.round(&sends);
            let staged: Vec<Vec<f32>> = (0..n)
                .map(|i| bufs[i][chunks[(i + 1 + n - r) % n].clone()].to_vec())
                .collect();
            for dst in 0..n {
                let src = (dst + n - 1) % n;
                let c = (src + 1 + n - r) % n;
                for (k, idx) in chunks[c].clone().enumerate() {
                    bufs[dst][idx] = staged[src][k];
                }
            }
        }
        ReduceReport {
            bytes_per_node: delta(net, &before),
            seconds: net.clock() - t0,
            density_per_hop: Vec::new(),
        }
    }

    pub fn sparse(net: &mut RingNet, inputs: &[SparseVec]) -> (Vec<f32>, ReduceReport) {
        let n = net.n_nodes();
        let len = inputs[0].len;
        let chunks = chunk_ranges(len, n);
        let before = snapshot(net);
        let t0 = net.clock();
        let segment = |s: &SparseVec, c: usize| -> SparseVec {
            let range = &chunks[c];
            let mut idx = Vec::new();
            let mut val = Vec::new();
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                let i = i as usize;
                if range.contains(&i) {
                    idx.push((i - range.start) as u32);
                    val.push(v);
                }
            }
            SparseVec {
                len: range.len(),
                idx,
                val,
            }
        };
        let mut held: Vec<SparseVec> = (0..n).map(|i| segment(&inputs[i], i)).collect();
        let mut density_per_hop = Vec::with_capacity(n - 1);
        for r in 0..n - 1 {
            let sends: Vec<u64> = held.iter().map(|s| s.wire_bytes()).collect();
            net.round(&sends);
            let next: Vec<SparseVec> = (0..n)
                .map(|dst| {
                    let src = (dst + n - 1) % n;
                    let c = (dst + n - (r + 1)) % n;
                    held[src].merge_add(&segment(&inputs[dst], c))
                })
                .collect();
            held = next;
            let d = held.iter().map(|s| s.density()).sum::<f64>() / n as f64;
            density_per_hop.push(d);
        }
        let mut result = vec![0.0f32; len];
        for (i, h) in held.iter().enumerate() {
            let range = chunks[(i + 1) % n].clone();
            for (&k, &v) in h.idx.iter().zip(&h.val) {
                result[range.start + k as usize] += v;
            }
        }
        for r in 0..n - 1 {
            let sends: Vec<u64> = (0..n)
                .map(|i| {
                    let c = (i + 1 + n - r) % n;
                    let seg_density: f64 = held[(c + n - 1) % n].density();
                    let nnz = (chunks[c].len() as f64 * seg_density).round() as usize;
                    SparseVec {
                        len: chunks[c].len(),
                        idx: vec![0; nnz.min(chunks[c].len())],
                        val: vec![0.0; nnz.min(chunks[c].len())],
                    }
                    .wire_bytes()
                })
                .collect();
            net.round(&sends);
        }
        (
            result,
            ReduceReport {
                bytes_per_node: delta(net, &before),
                seconds: net.clock() - t0,
                density_per_hop,
            },
        )
    }

    pub fn support(net: &mut RingNet, supports: &[BitMask]) -> ReduceReport {
        let n = net.n_nodes();
        let len = supports[0].len();
        let chunks = chunk_ranges_aligned(len, n);
        let before = snapshot(net);
        let t0 = net.clock();
        let mut held: Vec<Vec<u64>> = (0..n)
            .map(|i| supports[i].word_slice(chunks[i].clone()).to_vec())
            .collect();
        let mut density_per_hop = Vec::with_capacity(n - 1);
        let seg_bytes = |words: &[u64], chunk_len: usize| -> u64 {
            let nnz = BitMask::popcount_words(words);
            wire_bytes(WireFormat::cheapest(chunk_len, nnz), chunk_len, nnz)
        };
        for r in 0..n - 1 {
            let sends: Vec<u64> = (0..n)
                .map(|i| seg_bytes(&held[i], chunks[(i + n - r) % n].len()))
                .collect();
            net.round(&sends);
            let next: Vec<Vec<u64>> = (0..n)
                .map(|dst| {
                    let src = (dst + n - 1) % n;
                    let c = (dst + n - (r + 1)) % n;
                    let own = supports[dst].word_slice(chunks[c].clone());
                    let mut merged = held[src].clone();
                    for (m, o) in merged.iter_mut().zip(own) {
                        *m |= o;
                    }
                    merged
                })
                .collect();
            held = next;
            let (mut nnz, mut tot) = (0usize, 0usize);
            for (i, h) in held.iter().enumerate() {
                let c = (i + n - (r + 1)) % n;
                nnz += BitMask::popcount_words(h);
                tot += chunks[c].len();
            }
            density_per_hop.push(nnz as f64 / tot.max(1) as f64);
        }
        for r in 0..n - 1 {
            let sends: Vec<u64> = (0..n)
                .map(|i| {
                    let c = (i + 1 + n - r) % n;
                    seg_bytes(&held[(c + n - 1) % n], chunks[c].len())
                })
                .collect();
            net.round(&sends);
        }
        ReduceReport {
            bytes_per_node: delta(net, &before),
            seconds: net.clock() - t0,
            density_per_hop,
        }
    }

    pub fn masked(
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
    ) -> (BitMask, Vec<f32>, ReduceReport) {
        let n = net.n_nodes();
        let len = masks[0].len();
        let mask_bytes = masks[0].wire_bytes();
        let mut blobs = vec![0u64; n];
        for blob in blobs.iter_mut().take(masks.len().min(n)) {
            *blob = mask_bytes;
        }
        let t0 = net.clock();
        let before = snapshot(net);
        net.allgather(&blobs);
        let mut shared = BitMask::zeros(len);
        for m in masks {
            shared.or_assign(m);
        }
        let support: Vec<usize> = shared.iter_set().collect();
        let mut compact: Vec<Vec<f32>> = (0..n)
            .map(|node| support.iter().map(|&i| values[node][i]).collect())
            .collect();
        dense(net, &mut compact);
        let report = ReduceReport {
            bytes_per_node: delta(net, &before),
            seconds: net.clock() - t0,
            density_per_hop: vec![shared.density(); n.saturating_sub(1)],
        };
        (shared, compact.swap_remove(0), report)
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn arena_dense_matches_pre_refactor_golden_bit_for_bit() {
    for n in RING_SIZES {
        let len = 5000;
        let mut rng = Rng::new(31 + n as u64);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut net_g = net(n);
        let mut bufs_g = base.clone();
        let rep_g = golden::dense(&mut net_g, &mut bufs_g);
        let mut arena = Arena::for_nodes(n);
        for w in [1usize, 2, 4] {
            let mut net_a = net(n);
            let mut bufs_a = base.clone();
            let rep_a =
                ring::dense::allreduce_in(&mut net_a, &mut bufs_a, &Executor::new(w), &mut arena);
            assert_reports_identical(&rep_g, &rep_a, &format!("golden dense n={n} w={w}"));
            for (g, a) in bufs_g.iter().zip(&bufs_a) {
                assert_eq!(bits(g), bits(a), "golden dense n={n} w={w}: values");
            }
        }
    }
}

#[test]
fn arena_sparse_matches_pre_refactor_golden_bit_for_bit() {
    for n in RING_SIZES {
        let len = 4000;
        let mut rng = Rng::new(37 + n as u64);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.02))
            .collect();
        let mut net_g = net(n);
        let (sum_g, rep_g) = golden::sparse(&mut net_g, &inputs);
        let mut arena = Arena::for_nodes(n);
        for w in [1usize, 2, 4] {
            let mut net_a = net(n);
            let (sum_a, rep_a) =
                ring::sparse::allreduce_in(&mut net_a, &inputs, &Executor::new(w), &mut arena);
            assert_reports_identical(&rep_g, &rep_a, &format!("golden sparse n={n} w={w}"));
            assert_eq!(bits(&sum_g), bits(&sum_a), "golden sparse n={n} w={w}: sum");
        }
    }
}

#[test]
fn arena_support_matches_pre_refactor_golden_bit_for_bit() {
    for n in RING_SIZES {
        let len = 50_000;
        let mut rng = Rng::new(41 + n as u64);
        let supports: Vec<BitMask> = (0..n)
            .map(|_| {
                let mut m = BitMask::zeros(len);
                for _ in 0..500 {
                    m.set(rng.below(len));
                }
                m
            })
            .collect();
        let mut net_g = net(n);
        let rep_g = golden::support(&mut net_g, &supports);
        let mut arena = Arena::for_nodes(n);
        for w in [1usize, 2, 4] {
            let mut net_a = net(n);
            let rep_a = ring::sparse::allreduce_support_in(
                &mut net_a,
                &supports,
                &Executor::new(w),
                &mut arena,
            );
            assert_reports_identical(&rep_g, &rep_a, &format!("golden support n={n} w={w}"));
        }
    }
}

#[test]
fn arena_masked_matches_pre_refactor_golden_bit_for_bit() {
    for n in RING_SIZES {
        let len = 20_000;
        let mut rng = Rng::new(43 + n as u64);
        let mut mask_a = BitMask::zeros(len);
        let mut mask_b = BitMask::zeros(len);
        for _ in 0..300 {
            mask_a.set(rng.below(len));
            mask_b.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let mut net_g = net(n);
        let (shared_g, summed_g, rep_g) = golden::masked(&mut net_g, &[&mask_a, &mask_b], &refs);
        let mut arena = Arena::for_nodes(n);
        for w in [1usize, 2, 4] {
            let mut net_x = net(n);
            let (shared_x, summed_x, rep_x) = ring::masked::allreduce_in(
                &mut net_x,
                &[&mask_a, &mask_b],
                &refs,
                &Executor::new(w),
                &mut arena,
            );
            assert_eq!(shared_g, shared_x, "golden masked n={n} w={w}: mask");
            assert_reports_identical(&rep_g, &rep_x, &format!("golden masked n={n} w={w}"));
            assert_eq!(bits(&summed_g), bits(&summed_x), "golden masked n={n} w={w}");
        }
    }
}

// ---- arena zero-alloc steady state ------------------------------------

#[test]
fn arena_schedules_have_zero_steady_state_reallocations() {
    let n = 8;
    let len = 6000;
    let mut rng = Rng::new(53);
    let base: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let inputs: Vec<SparseVec> = (0..n).map(|_| random_sparse(&mut rng, len, 0.02)).collect();
    let supports: Vec<BitMask> = (0..n)
        .map(|_| {
            let mut m = BitMask::zeros(len);
            for _ in 0..100 {
                m.set(rng.below(len));
            }
            m
        })
        .collect();
    let mut mask = BitMask::zeros(len);
    for _ in 0..200 {
        mask.set(rng.below(len));
    }
    let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();

    let exec = Executor::sequential();
    let mut arena = Arena::for_nodes(n);
    let run_all = |arena: &mut Arena| {
        let mut nw = net(n);
        let mut bufs = base.clone();
        ring::dense::allreduce_in(&mut nw, &mut bufs, &exec, arena);
        let mut nw = net(n);
        ring::sparse::allreduce_in(&mut nw, &inputs, &exec, arena);
        let mut nw = net(n);
        ring::sparse::allreduce_support_in(&mut nw, &supports, &exec, arena);
        let mut nw = net(n);
        ring::masked::allreduce_in(&mut nw, &[&mask], &refs, &exec, arena);
        let mut nw = net(n);
        ring::masked::allreduce_bytes_only_in(&mut nw, &[&mask], arena);
        let mut nw = net(n);
        ring::dense::rounds_bytes_only(&mut nw, len, arena);
    };
    run_all(&mut arena); // warm-up
    let warm = arena.grows();
    assert!(warm > 0, "warm-up must populate the arena");
    for pass in 0..3 {
        run_all(&mut arena);
        assert_eq!(
            arena.grows(),
            warm,
            "steady-state pass {pass} reallocated arena buffers"
        );
    }
}

#[test]
fn engine_arena_is_allocation_free_after_first_step() {
    // Baseline and DGC have shape-stable arena footprints (the IWP
    // support size is data-dependent per step, so it is pinned at the
    // schedule level above instead).
    for method in [Method::Baseline, Method::Dgc] {
        let cfg = SimCfg {
            nodes: 8,
            method: method.spec(),
            seed: 29,
            link: LinkSpec::gigabit_ethernet(),
            ..Default::default()
        };
        let mut engine = SimEngine::new(sim_layout(), cfg);
        engine.step(0);
        let warm = engine.arena().grows();
        for s in 1..5 {
            engine.step(s);
            assert_eq!(
                engine.arena().grows(),
                warm,
                "{method:?}: step {s} reallocated arena buffers"
            );
        }
    }
}

#[test]
fn sim_engine_parallel_is_bit_identical_across_methods_and_ring_sizes() {
    for method in [
        Method::Baseline,
        Method::TernGrad,
        Method::Dgc,
        Method::IwpFixed,
        Method::IwpLayerwise,
    ] {
        for nodes in [4usize, 8, 96] {
            let (seq_reports, seq_ratio) = run_engine(method, nodes, 1);
            for w in [2usize, 4] {
                let (par_reports, par_ratio) = run_engine(method, nodes, w);
                assert_eq!(
                    seq_reports, par_reports,
                    "{method:?} nodes={nodes} w={w}: step reports diverged"
                );
                assert_eq!(
                    seq_ratio.to_bits(),
                    par_ratio.to_bits(),
                    "{method:?} nodes={nodes} w={w}: ratio diverged"
                );
            }
        }
    }
}
