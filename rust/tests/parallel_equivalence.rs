//! The parallel executor's contract (DESIGN.md §4): for every schedule
//! and engine, `parallelism = W` produces **bit-identical** results to
//! the sequential oracle (`parallelism = 1`) — same `ReduceReport`
//! (bytes, virtual seconds, density per hop), same reduced values, same
//! RNG evolution. No tolerance comparisons here: equality is exact.

use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, RingNet};
use ringiwp::ring::{self, Executor, ReduceReport};
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::prop::forall;
use ringiwp::util::rng::Rng;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::gigabit_ethernet(), 0.05)
}

fn assert_reports_identical(seq: &ReduceReport, par: &ReduceReport, ctx: &str) {
    assert_eq!(seq.bytes_per_node, par.bytes_per_node, "{ctx}: bytes");
    assert_eq!(
        seq.seconds.to_bits(),
        par.seconds.to_bits(),
        "{ctx}: seconds {} vs {}",
        seq.seconds,
        par.seconds
    );
    let db = |r: &ReduceReport| -> Vec<u64> {
        r.density_per_hop.iter().map(|d| d.to_bits()).collect()
    };
    assert_eq!(db(seq), db(par), "{ctx}: density_per_hop");
}

fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> SparseVec {
    let mut dense = vec![0.0f32; len];
    for v in dense.iter_mut() {
        if (rng.uniform() as f64) < density {
            *v = rng.normal();
        }
    }
    SparseVec::from_dense(&dense)
}

const RING_SIZES: [usize; 3] = [4, 8, 96];
const WORKERS: [usize; 3] = [2, 4, 8];

#[test]
fn dense_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 6000;
        let mut rng = Rng::new(7 + n as u64);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut net_seq = net(n);
        let mut bufs_seq = base.clone();
        let rep_seq = ring::dense::allreduce(&mut net_seq, &mut bufs_seq);
        for w in WORKERS {
            let mut net_par = net(n);
            let mut bufs_par = base.clone();
            let rep_par =
                ring::dense::allreduce_exec(&mut net_par, &mut bufs_par, &Executor::new(w));
            assert_reports_identical(&rep_seq, &rep_par, &format!("dense n={n} w={w}"));
            for (s, p) in bufs_seq.iter().zip(&bufs_par) {
                let sb: Vec<u32> = s.iter().map(|v| v.to_bits()).collect();
                let pb: Vec<u32> = p.iter().map(|v| v.to_bits()).collect();
                assert_eq!(sb, pb, "dense n={n} w={w}: reduced values");
            }
            assert_eq!(net_seq.clock().to_bits(), net_par.clock().to_bits());
        }
    }
}

#[test]
fn sparse_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 4000;
        let mut rng = Rng::new(11 + n as u64);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.02))
            .collect();
        let mut net_seq = net(n);
        let (sum_seq, rep_seq) = ring::sparse::allreduce(&mut net_seq, &inputs);
        for w in WORKERS {
            let mut net_par = net(n);
            let (sum_par, rep_par) =
                ring::sparse::allreduce_exec(&mut net_par, &inputs, &Executor::new(w));
            assert_reports_identical(&rep_seq, &rep_par, &format!("sparse n={n} w={w}"));
            let sb: Vec<u32> = sum_seq.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = sum_par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "sparse n={n} w={w}: reduced values");
        }
    }
}

#[test]
fn sparse_support_path_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 50_000;
        let mut rng = Rng::new(13 + n as u64);
        let supports: Vec<BitMask> = (0..n)
            .map(|_| {
                let mut m = BitMask::zeros(len);
                for _ in 0..500 {
                    m.set(rng.below(len));
                }
                m
            })
            .collect();
        let mut net_seq = net(n);
        let rep_seq = ring::sparse::allreduce_support(&mut net_seq, &supports);
        for w in WORKERS {
            let mut net_par = net(n);
            let rep_par = ring::sparse::allreduce_support_exec(
                &mut net_par,
                &supports,
                &Executor::new(w),
            );
            assert_reports_identical(&rep_seq, &rep_par, &format!("support n={n} w={w}"));
        }
    }
}

#[test]
fn masked_schedule_parallel_is_bit_identical() {
    for n in RING_SIZES {
        let len = 20_000;
        let mut rng = Rng::new(17 + n as u64);
        let mut mask_a = BitMask::zeros(len);
        let mut mask_b = BitMask::zeros(len);
        for _ in 0..300 {
            mask_a.set(rng.below(len));
            mask_b.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let mut net_seq = net(n);
        let (shared_seq, summed_seq, rep_seq) =
            ring::masked::allreduce(&mut net_seq, &[&mask_a, &mask_b], &refs);
        for w in WORKERS {
            let mut net_par = net(n);
            let (shared_par, summed_par, rep_par) = ring::masked::allreduce_exec(
                &mut net_par,
                &[&mask_a, &mask_b],
                &refs,
                &Executor::new(w),
            );
            assert_eq!(shared_seq, shared_par, "masked n={n} w={w}: shared mask");
            assert_reports_identical(&rep_seq, &rep_par, &format!("masked n={n} w={w}"));
            let sb: Vec<u32> = summed_seq.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = summed_par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "masked n={n} w={w}: summed values");
        }
    }
}

/// Satellite property: across random seeds/shapes, every schedule's
/// parallel report equals the sequential one exactly.
#[test]
fn reduce_report_equality_property_across_seeds() {
    forall("parallel ReduceReport == sequential", 20, |g| {
        let n = g.usize_in(2, 10);
        let len = g.usize_in(n.max(8), 600);
        let workers = g.choice(&[2usize, 3, 5, 8]);
        let exec = Executor::new(workers);
        let seed = g.rng().next_u64();
        let mut rng = Rng::new(seed);

        // Dense.
        let base: Vec<Vec<f32>> = (0..n).map(|_| {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        }).collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (mut ba, mut bb) = (base.clone(), base);
        let ra = ring::dense::allreduce(&mut na, &mut ba);
        let rb = ring::dense::allreduce_exec(&mut nb, &mut bb, &exec);
        assert_reports_identical(&ra, &rb, &format!("prop dense seed={seed}"));

        // Sparse.
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.1))
            .collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (va, ra) = ring::sparse::allreduce(&mut na, &inputs);
        let (vb, rb) = ring::sparse::allreduce_exec(&mut nb, &inputs, &exec);
        assert_reports_identical(&ra, &rb, &format!("prop sparse seed={seed}"));
        assert_eq!(
            va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        // Masked.
        let mut mask = BitMask::zeros(len);
        for _ in 0..len / 4 {
            mask.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let (mut na, mut nb) = (net(n), net(n));
        let (sa, va, ra) = ring::masked::allreduce(&mut na, &[&mask], &refs);
        let (sb, vb, rb) = ring::masked::allreduce_exec(&mut nb, &[&mask], &refs, &exec);
        assert_eq!(sa, sb);
        assert_reports_identical(&ra, &rb, &format!("prop masked seed={seed}"));
        assert_eq!(
            va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    });
}

// ---- engine-level equivalence -----------------------------------------

fn sim_layout() -> ParamLayout {
    ParamLayout::new(
        "sim_eq",
        vec![
            ("conv1".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn1".into(), vec![64], LayerKind::BatchNorm),
            ("conv2".into(), vec![64, 32, 3, 3], LayerKind::Conv),
            ("fc".into(), vec![512, 10], LayerKind::Fc),
            ("bias".into(), vec![10], LayerKind::Bias),
        ],
    )
}

fn run_engine(method: Method, nodes: usize, parallelism: usize) -> (Vec<(u64, u64, u64)>, f64) {
    let cfg = SimCfg {
        nodes,
        method,
        parallelism,
        link: LinkSpec::gigabit_ethernet(),
        seed: 23,
        ..Default::default()
    };
    let mut engine = SimEngine::new(sim_layout(), cfg);
    let mut reports = Vec::new();
    for s in 0..3 {
        let r = engine.step(s);
        reports.push((
            r.wire_bytes_per_node,
            r.density.to_bits(),
            r.seconds.to_bits(),
        ));
    }
    (reports, engine.account.ratio())
}

#[test]
fn sim_engine_parallel_is_bit_identical_across_methods_and_ring_sizes() {
    for method in [
        Method::Baseline,
        Method::TernGrad,
        Method::Dgc,
        Method::IwpFixed,
        Method::IwpLayerwise,
    ] {
        for nodes in [4usize, 8, 96] {
            let (seq_reports, seq_ratio) = run_engine(method, nodes, 1);
            for w in [2usize, 4] {
                let (par_reports, par_ratio) = run_engine(method, nodes, w);
                assert_eq!(
                    seq_reports, par_reports,
                    "{method:?} nodes={nodes} w={w}: step reports diverged"
                );
                assert_eq!(
                    seq_ratio.to_bits(),
                    par_ratio.to_bits(),
                    "{method:?} nodes={nodes} w={w}: ratio diverged"
                );
            }
        }
    }
}
