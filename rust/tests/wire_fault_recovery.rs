//! Wire-fault recovery golden suite (DESIGN.md §16).
//!
//! The self-healing contract, pinned to the sim oracle:
//!
//! * **recoverable schedules are invisible** — a socket engine running
//!   under a seeded byte-level fault plan (bit flips, truncations,
//!   drops, duplicates, delays, connection resets) must produce
//!   `StepReport`s bit-identical to a fault-free virtual `SimEngine`,
//!   for every bench pipeline × reduce topology, with the recovery
//!   counters proving the faults actually fired;
//! * **the empty plan is free** — carrying `FaultPlan::default()`
//!   is bit-identical to carrying no plan at all, and records zero
//!   recovery activity;
//! * **unrecoverable schedules fail loudly** — a cell scheduled with
//!   more faults than the attempt budget surfaces the typed
//!   [`WireError::Exhausted`] ("retry budget exhausted"), never a
//!   silent wrong answer.
//!
//! Every socket-touching test runs under the same hard watchdog as
//! `chaos_equivalence.rs` — a wedged ARQ fails in bounded time.

use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use ringiwp::compress::MethodSpec;
use ringiwp::exp::bench::step_specs;
use ringiwp::exp::simrun::{SimCfg, SimEngine, StepReport, WireEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{FaultPlan, LinkSpec, TopoKind, TransportKind};

const WATCHDOG: Duration = Duration::from_secs(180);

/// Run `f` on its own thread and fail loudly if it outlives the
/// watchdog; panics inside `f` propagate to the harness unchanged.
fn with_watchdog<F>(label: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let worker = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            f();
            let _ = tx.send(());
        })
        .expect("spawn watchdog worker");
    match rx.recv_timeout(WATCHDOG) {
        Ok(()) | Err(RecvTimeoutError::Disconnected) => {
            if let Err(panic) = worker.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("{label}: still running after {WATCHDOG:?} — ARQ deadlock");
        }
    }
}

fn layout() -> ParamLayout {
    ParamLayout::new(
        "fault_recovery",
        vec![
            ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn".into(), vec![67], LayerKind::BatchNorm),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
        ],
    )
}

fn cfg(spec: &str, topology: TopoKind, faults: Option<FaultPlan>) -> SimCfg {
    SimCfg {
        nodes: 5,
        method: MethodSpec::parse(spec).expect("registry spec"),
        link: LinkSpec::new(1e9, 1e-5),
        topology,
        transport: TransportKind::Sim,
        wire_dir: None,
        seed: 42,
        steps_per_epoch: 3,
        warmup_epochs: 1,
        chaos: None,
        wire_faults: faults,
        // Short deadline so drop/truncation stalls resolve in test
        // time; the ARQ retry + ACK deadlines derive from this knob.
        wire_timeout_ms: 5_000,
        ..Default::default()
    }
}

/// A recoverable schedule exercising every fault family: a bit flip, a
/// truncation, a drop, a duplicate, a delay, and a connection reset —
/// all landing on first-step frames so every topology hits them.
fn recoverable_plan() -> FaultPlan {
    FaultPlan::parse("seed=11,flip@0:0,trunc@1:3,drop@0:2,dup@1:1,delay@2:0:3,reset@2:2")
        .expect("static plan")
}

fn assert_reports_identical(ctx: &str, step: usize, a: &StepReport, b: &StepReport) {
    assert_eq!(
        a.wire_bytes_per_node, b.wire_bytes_per_node,
        "{ctx} step {step}: wire_bytes_per_node"
    );
    assert_eq!(a.support_nnz, b.support_nnz, "{ctx} step {step}: support_nnz");
    assert_eq!(
        a.density.to_bits(),
        b.density.to_bits(),
        "{ctx} step {step}: density ({} vs {})",
        a.density,
        b.density
    );
    assert_eq!(
        a.seconds.to_bits(),
        b.seconds.to_bits(),
        "{ctx} step {step}: seconds ({} vs {})",
        a.seconds,
        b.seconds
    );
    assert_eq!(
        a.wire_seconds.to_bits(),
        b.wire_seconds.to_bits(),
        "{ctx} step {step}: wire_seconds ({} vs {})",
        a.wire_seconds,
        b.wire_seconds
    );
}

/// One faulted uds run vs the fault-free sim oracle; returns nothing —
/// panics carry the config context.
fn assert_faulted_run_matches_oracle(spec: &str, topo: TopoKind) {
    let ctx = format!("{spec}/{}", topo.name());
    let mut sim = SimEngine::new(layout(), cfg(spec, topo, None));
    let mut c = cfg(spec, topo, Some(recoverable_plan()));
    c.transport = TransportKind::Uds;
    let mut wire =
        WireEngine::new(layout(), c).unwrap_or_else(|e| panic!("{ctx}: wire construction: {e}"));
    for s in 0..3 {
        let a = sim.step(s);
        let w = wire.step(s);
        assert_reports_identical(&ctx, s, &a, &w.report);
        assert!(w.real_bytes > 0, "{ctx} step {s}: no real bytes");
    }
    wire.shutdown().unwrap_or_else(|e| panic!("{ctx}: shutdown: {e}"));
    let rec = wire.recovery_stats();
    assert!(
        rec.retransmits >= 1,
        "{ctx}: flip/trunc/drop faults must force retransmits — {rec}"
    );
    assert!(
        rec.reconnects >= 1,
        "{ctx}: the reset fault must force a reconnect — {rec}"
    );
    assert!(
        rec.dup_drops >= 1,
        "{ctx}: the dup fault must be suppressed — {rec}"
    );
}

#[test]
fn faulted_uds_matches_sim_for_every_spec_on_ring_topologies() {
    // First half of the spec × topology matrix: the flat paper ring
    // and the hierarchical reduce.
    with_watchdog("faults-flat-hier", || {
        for spec in step_specs() {
            for topo in [TopoKind::Flat, TopoKind::Hier { group: 4 }] {
                assert_faulted_run_matches_oracle(&spec.name(), topo);
            }
        }
    });
}

#[test]
fn faulted_uds_matches_sim_for_every_spec_on_tree_and_pipeline() {
    // Second half of the matrix: tree reduce and the chunked pipeline.
    with_watchdog("faults-tree-pipeline", || {
        for spec in step_specs() {
            for topo in [TopoKind::Tree, TopoKind::parse("pipeline:4:flat").unwrap()] {
                assert_faulted_run_matches_oracle(&spec.name(), topo);
            }
        }
    });
}

#[test]
fn empty_plan_is_bit_identical_to_no_plan_with_zero_recovery() {
    // The zero-overhead contract: an engine carrying the empty plan
    // must not move a single bit of a healthy run, and its counters
    // must stay at zero.
    with_watchdog("empty-plan", || {
        let run = |faults: Option<FaultPlan>| -> (Vec<StepReport>, u64) {
            let mut c = cfg("iwp:fixed", TopoKind::Flat, faults);
            c.transport = TransportKind::Uds;
            let mut wire = WireEngine::new(layout(), c).expect("wire construction");
            let reports = (0..3).map(|s| wire.step(s).report).collect();
            wire.shutdown().expect("shutdown");
            let rec = wire.recovery_stats();
            (reports, rec.total_events())
        };
        let (bare, bare_events) = run(None);
        let (empty, empty_events) = run(Some(FaultPlan::default()));
        for (s, (a, b)) in bare.iter().zip(&empty).enumerate() {
            assert_reports_identical("empty-plan", s, a, b);
        }
        assert_eq!(bare_events, 0, "fault-free run must record no recovery");
        assert_eq!(empty_events, 0, "empty plan must record no recovery");
    });
}

#[test]
fn drop_faults_recover_through_the_shortened_ack_deadline() {
    // A swallowed frame is the slowest fault (nothing arrives, the
    // sender must time out): with a small --wire-timeout-ms the ACK
    // deadline shrinks and recovery still reproduces the oracle.
    with_watchdog("drop-fault", || {
        let plan = FaultPlan::parse("seed=3,drop@0:0,drop@1:2").expect("static plan");
        let mut sim = SimEngine::new(layout(), cfg("iwp:fixed", TopoKind::Flat, None));
        let mut c = cfg("iwp:fixed", TopoKind::Flat, Some(plan));
        c.transport = TransportKind::Uds;
        c.wire_timeout_ms = 1_500;
        let mut wire = WireEngine::new(layout(), c).expect("wire construction");
        for s in 0..3 {
            let a = sim.step(s);
            let w = wire.step(s);
            assert_reports_identical("drop-fault", s, &a, &w.report);
        }
        wire.shutdown().expect("shutdown");
        let rec = wire.recovery_stats();
        assert!(rec.retransmits >= 2, "both drops must retransmit — {rec}");
    });
}

#[test]
fn exhausted_retry_budget_fails_loudly_with_the_typed_error() {
    // Unrecoverable by construction: attempts=2 with two faults piled
    // on the same (frame, edge) cell — every attempt is damaged, the
    // budget runs out, and the run must die with the typed Exhausted
    // error (wire seam panic carrying its Display), never a silently
    // wrong report stream.
    with_watchdog("exhausted", || {
        let plan =
            FaultPlan::parse("attempts=2,seed=7,drop@0:0,drop@0:0").expect("static plan");
        let mut c = cfg("iwp:fixed", TopoKind::Flat, Some(plan));
        c.transport = TransportKind::Uds;
        c.wire_timeout_ms = 1_000;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut wire = WireEngine::new(layout(), c).expect("wire construction");
            for s in 0..2 {
                let _ = wire.step(s);
            }
            let _ = wire.shutdown();
        }));
        let panic = outcome.expect_err("unrecoverable schedule must not succeed");
        let msg = panic
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.to_lowercase().contains("exhausted"),
            "panic must carry the typed Exhausted error, got: {msg}"
        );
    });
}
