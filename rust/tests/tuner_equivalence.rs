//! Autotuner oracle suite (DESIGN.md §14).
//!
//! The `CostModel` closed forms are the specification; `net::tuner` is
//! the implementation under test. Three contracts:
//!
//! * **Argmin bit-exactness** — at the default margin (0), every
//!   decision's predicted cost equals the minimum over the candidate
//!   grid bit for bit, recomputed independently here from the same
//!   observation.
//! * **Never-worse** — over AlexNet/ResNet50-shaped density
//!   trajectories, the tuner's cumulative predicted wire-seconds is
//!   ≤ every static strategy's cumulative prediction (both re-derived
//!   from the decision trace's `considered` columns, summed in the
//!   same fold order, so f64 rounding cannot flip the inequality).
//! * **Determinism** — decisions are pure data: identical across
//!   `--parallelism` widths and across the sim/uds transports (masks
//!   travel and decode *before* the tuner observes them).
//!
//! The socket-touching test runs under a hard watchdog so a deadlocked
//! ring fails in bounded time instead of hanging the suite.

use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use ringiwp::compress::MethodSpec;
use ringiwp::exp::simrun::{SimCfg, SimEngine, WireEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, Observation, TransportKind, Tuner, TunerMode};
use ringiwp::sparse::BitMask;
use ringiwp::util::rng::Rng;

const WATCHDOG: Duration = Duration::from_secs(180);

fn with_watchdog<F>(label: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let worker = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            f();
            let _ = tx.send(());
        })
        .expect("spawn watchdog worker");
    match rx.recv_timeout(WATCHDOG) {
        Ok(()) | Err(RecvTimeoutError::Disconnected) => {
            if let Err(panic) = worker.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("{label}: still running after {WATCHDOG:?} — ring deadlock");
        }
    }
}

/// AlexNet-shaped micro inventory: conv stack into heavy fc layers —
/// the fc-dominated density trajectory of the real 61M inventory.
fn alexnet_micro() -> ParamLayout {
    ParamLayout::new(
        "alexnet_micro",
        vec![
            ("conv1".into(), vec![16, 3, 3, 3], LayerKind::Conv),
            ("conv2".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("fc1".into(), vec![256, 64], LayerKind::Fc),
            ("fc2".into(), vec![64, 10], LayerKind::Fc),
            ("bias".into(), vec![10], LayerKind::Bias),
        ],
    )
}

/// ResNet50-shaped micro inventory: conv/batchnorm alternation.
fn resnet50_micro() -> ParamLayout {
    ParamLayout::new(
        "resnet50_micro",
        vec![
            ("conv1".into(), vec![16, 3, 7, 7], LayerKind::Conv),
            ("bn1".into(), vec![32], LayerKind::BatchNorm),
            ("block1".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn2".into(), vec![64], LayerKind::BatchNorm),
            ("block2".into(), vec![64, 32, 3, 3], LayerKind::Conv),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
        ],
    )
}

fn cfg(nodes: usize, tuner: TunerMode) -> SimCfg {
    SimCfg {
        nodes,
        method: MethodSpec::parse("iwp:fixed").expect("registry spec"),
        link: LinkSpec::gigabit_ethernet(),
        transport: TransportKind::Sim,
        wire_dir: None,
        seed: 42,
        tuner,
        ..Default::default()
    }
}

const STEPS: usize = 6;

/// Argmin bit-exactness + never-worse, over both model trajectories.
/// Runs in log-only mode: the static path executes (so the density
/// trajectory is the canonical one) while the trace records what the
/// tuner priced and picked each step.
#[test]
fn picks_are_the_argmin_and_never_worse_on_both_trajectories() {
    for layout in [alexnet_micro(), resnet50_micro()] {
        let model = layout.model.clone();
        let mut e = SimEngine::new(layout, cfg(8, TunerMode::LogOnly));
        for s in 0..STEPS {
            e.step(s);
        }
        let t = e.tuner().expect("log-only builds a tuner");
        let trace = t.trace();
        assert_eq!(trace.len(), STEPS, "{model}: one decision per step");
        for row in trace.rows() {
            // The pick's predicted cost IS the grid minimum, bit for bit
            // (margin 0 holds the incumbent only on exact ties).
            let min = row
                .considered
                .iter()
                .map(|(_, s)| *s)
                .fold(f64::INFINITY, f64::min);
            assert_eq!(
                row.predicted_s.to_bits(),
                min.to_bits(),
                "{model} step {}: pick `{}` predicted {} but grid min is {}",
                row.step,
                row.pick,
                row.predicted_s,
                min
            );
            assert!(row.support_nnz > 0, "{model}: IWP masks are never empty");
        }
        // Cumulative never-worse against every static strategy.
        let picked = trace.picked_total();
        for (i, s) in t.candidates().iter().enumerate() {
            let static_total = trace.static_total(i);
            assert!(
                picked <= static_total,
                "{model}: tuner total {picked} exceeds static `{}` total {static_total}",
                s.name()
            );
        }
    }
}

/// On-mode decisions (which feed back into execution and the observed
/// trajectory) are still the per-step argmin of their own trace rows.
#[test]
fn on_mode_executes_its_own_argmin() {
    let mut e = SimEngine::new(alexnet_micro(), cfg(8, TunerMode::On));
    for s in 0..STEPS {
        let r = e.step(s);
        assert!(r.wire_bytes_per_node > 0, "step {s}");
    }
    let t = e.tuner().expect("tuner on");
    assert_eq!(t.trace().len(), STEPS);
    for row in t.trace().rows() {
        let min = row
            .considered
            .iter()
            .map(|(_, s)| *s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(row.predicted_s.to_bits(), min.to_bits(), "step {}", row.step);
    }
}

/// Hysteresis contract: a margin holds the incumbent against small
/// oscillations — an observation stream that flips between two nearby
/// supports must not flip the strategy back and forth.
#[test]
fn hysteresis_margin_prevents_flip_flop() {
    let coords = 40_000;
    let mut rng = Rng::new(7);
    let mk = |nnz: usize, rng: &mut Rng| {
        let mut m = BitMask::zeros(coords);
        while m.count() < nnz {
            m.set(rng.below(coords));
        }
        m
    };
    let a = mk(400, &mut rng);
    let b = mk(440, &mut rng);
    let mut damped =
        Tuner::new(TunerMode::On, 8, LinkSpec::gigabit_ethernet()).with_margin(0.5);
    for step in 0..10 {
        let m = if step % 2 == 0 { &a } else { &b };
        damped.decide(&Observation {
            coords,
            k: 1,
            shared: m,
        });
    }
    assert_eq!(
        damped.switches(),
        0,
        "a 50% margin must hold the incumbent across ±10% support wobble"
    );
    assert_eq!(damped.trace().switches(), 0);
}

/// Decisions and reports are bit-identical at any executor width — the
/// §4 contract extends through the tuner (decisions are computed from
/// pure data on the coordinating thread).
#[test]
fn tuned_run_is_bit_identical_across_parallelism() {
    let run = |parallelism: usize| {
        let mut c = cfg(8, TunerMode::On);
        c.parallelism = parallelism;
        let mut e = SimEngine::new(resnet50_micro(), c);
        let reports: Vec<_> = (0..STEPS).map(|s| e.step(s)).collect();
        let picks: Vec<String> = e
            .tuner()
            .unwrap()
            .trace()
            .rows()
            .iter()
            .map(|r| r.pick.clone())
            .collect();
        (reports, picks)
    };
    let (seq, seq_picks) = run(1);
    let (par, par_picks) = run(4);
    assert_eq!(seq_picks, par_picks, "picks must not depend on executor width");
    for (s, (a, b)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "step {s}");
        assert_eq!(a.support_nnz, b.support_nnz, "step {s}");
        assert_eq!(a.density.to_bits(), b.density.to_bits(), "step {s}");
        assert_eq!(a.wire_seconds.to_bits(), b.wire_seconds.to_bits(), "step {s}");
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "step {s}");
    }
}

/// Transport invariance: masks spread and decode *before* the tuner
/// observes them, so a UDS ring must produce the same decisions and
/// bit-identical reports as the pure simulation — even while the tuner
/// switches wire formats underneath.
#[test]
fn tuned_run_over_uds_matches_sim_bit_for_bit() {
    with_watchdog("tuned-uds", || {
        let layout = alexnet_micro();
        let mut c = cfg(4, TunerMode::On);
        c.transport = TransportKind::Uds;
        let mut sim = SimEngine::new(layout.clone(), c.clone());
        let mut wire = WireEngine::new(layout, c).expect("uds ring");
        for s in 0..STEPS {
            let a = sim.step(s);
            let b = wire.step(s).report;
            assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "step {s}");
            assert_eq!(a.support_nnz, b.support_nnz, "step {s}");
            assert_eq!(a.density.to_bits(), b.density.to_bits(), "step {s}");
            assert_eq!(a.wire_seconds.to_bits(), b.wire_seconds.to_bits(), "step {s}");
        }
        let sp: Vec<String> = sim
            .tuner()
            .unwrap()
            .trace()
            .rows()
            .iter()
            .map(|r| r.pick.clone())
            .collect();
        let wp: Vec<String> = wire
            .sim()
            .tuner()
            .unwrap()
            .trace()
            .rows()
            .iter()
            .map(|r| r.pick.clone())
            .collect();
        assert_eq!(sp, wp, "picks must not depend on the transport");
        wire.shutdown().expect("clean shutdown");
    });
}

/// Log-only is a pure observer: every report is bit-identical to a
/// tuner-off run on the same seeds.
#[test]
fn log_only_is_bit_identical_to_off() {
    let layout = resnet50_micro();
    let mut off = SimEngine::new(layout.clone(), cfg(8, TunerMode::Off));
    let mut log = SimEngine::new(layout, cfg(8, TunerMode::LogOnly));
    for s in 0..STEPS {
        let a = off.step(s);
        let b = log.step(s);
        assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "step {s}");
        assert_eq!(a.support_nnz, b.support_nnz, "step {s}");
        assert_eq!(a.density.to_bits(), b.density.to_bits(), "step {s}");
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "step {s}");
    }
    assert!(off.tuner().is_none());
    assert_eq!(log.tuner().unwrap().trace().len(), STEPS);
}
