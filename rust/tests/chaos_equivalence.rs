//! Chaos determinism + zero-overhead golden suite (DESIGN.md §15).
//!
//! Two contracts:
//!
//! * **zero overhead** — an engine carrying the empty (no-fault)
//!   [`ChaosPlan`] produces `StepReport`s bit-identical to an engine
//!   with no plan at all, for every bench pipeline × topology: wiring
//!   the chaos seam in must not move a single bit of a healthy run;
//! * **determinism through faults** — the same seed and the same fault
//!   schedule yield bit-identical report streams at any executor
//!   parallelism and on either transport (the virtual simulator vs a
//!   real socket ring that tears down and re-rings on every membership
//!   event). Crashes, stragglers, joins, and heals are all replayed —
//!   recovery itself must be deterministic, not just tolerated.
//!
//! Every socket-touching test runs under a hard watchdog: a deadlocked
//! re-ring fails in bounded time instead of hanging the suite (CI adds
//! an outer `timeout` as the backstop).

use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use ringiwp::compress::MethodSpec;
use ringiwp::exp::bench::step_specs;
use ringiwp::exp::simrun::{SimCfg, SimEngine, StepReport, WireEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{ChaosPlan, LinkSpec, RecoveryMode, TopoKind, TransportKind};

const WATCHDOG: Duration = Duration::from_secs(180);

/// Run `f` on its own thread and fail loudly if it outlives the
/// watchdog; panics inside `f` propagate to the harness unchanged.
fn with_watchdog<F>(label: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let worker = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            f();
            let _ = tx.send(());
        })
        .expect("spawn watchdog worker");
    match rx.recv_timeout(WATCHDOG) {
        Ok(()) | Err(RecvTimeoutError::Disconnected) => {
            if let Err(panic) = worker.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("{label}: still running after {WATCHDOG:?} — ring deadlock");
        }
    }
}

fn layout() -> ParamLayout {
    ParamLayout::new(
        "chaos_equiv",
        vec![
            ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn".into(), vec![67], LayerKind::BatchNorm),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
        ],
    )
}

fn cfg(spec: &str, nodes: usize, topology: TopoKind, chaos: Option<ChaosPlan>) -> SimCfg {
    SimCfg {
        nodes,
        method: MethodSpec::parse(spec).expect("registry spec"),
        link: LinkSpec::new(1e9, 1e-5),
        topology,
        transport: TransportKind::Sim,
        wire_dir: None,
        seed: 42,
        steps_per_epoch: 3,
        warmup_epochs: 1,
        chaos,
        ..Default::default()
    }
}

fn assert_reports_identical(ctx: &str, step: usize, a: &StepReport, b: &StepReport) {
    assert_eq!(
        a.wire_bytes_per_node, b.wire_bytes_per_node,
        "{ctx} step {step}: wire_bytes_per_node"
    );
    assert_eq!(a.support_nnz, b.support_nnz, "{ctx} step {step}: support_nnz");
    assert_eq!(
        a.density.to_bits(),
        b.density.to_bits(),
        "{ctx} step {step}: density ({} vs {})",
        a.density,
        b.density
    );
    assert_eq!(
        a.seconds.to_bits(),
        b.seconds.to_bits(),
        "{ctx} step {step}: seconds ({} vs {})",
        a.seconds,
        b.seconds
    );
    assert_eq!(
        a.wire_seconds.to_bits(),
        b.wire_seconds.to_bits(),
        "{ctx} step {step}: wire_seconds ({} vs {})",
        a.wire_seconds,
        b.wire_seconds
    );
}

/// The sweep's fault schedule: one crash, one straggler, one join, one
/// heal — every recovery path fires within 6 steps on a 5-node ring.
fn plan(mode: RecoveryMode) -> ChaosPlan {
    let mut p = ChaosPlan::parse("crash@1:1,slow@2:0:4,join@4,heal@5").expect("static plan");
    p.mode = mode;
    p
}

fn topologies() -> [TopoKind; 4] {
    [
        TopoKind::Flat,
        TopoKind::Hier { group: 2 },
        TopoKind::Tree,
        TopoKind::parse("pipeline:2:flat").unwrap(),
    ]
}

#[test]
fn no_fault_plan_is_bit_identical_for_every_spec_and_topology() {
    // The zero-overhead contract over the full bench matrix: carrying
    // an empty plan must not perturb RNG streams, link tables, or any
    // report bit.
    for spec in step_specs() {
        for topo in topologies() {
            let ctx = format!("{}/{}", spec.name(), topo.name());
            let mut bare = SimEngine::new(layout(), cfg(&spec.name(), 5, topo, None));
            let mut empty =
                SimEngine::new(layout(), cfg(&spec.name(), 5, topo, Some(ChaosPlan::none())));
            for s in 0..3 {
                let a = bare.step(s);
                let b = empty.step(s);
                assert_reports_identical(&ctx, s, &a, &b);
            }
        }
    }
}

#[test]
fn faulted_streams_are_bit_identical_across_parallelism() {
    // Same seed + same schedule at executor widths 1 (the sequential
    // oracle), 2, and 4: recovery re-rings must preserve the
    // parallelism-independence contract (DESIGN.md §4).
    for mode in [RecoveryMode::Handoff, RecoveryMode::DropRescale] {
        for spec in ["iwp:fixed", "dgc:topk"] {
            let run = |par: usize| -> Vec<StepReport> {
                let mut c = cfg(spec, 5, TopoKind::Flat, Some(plan(mode)));
                c.parallelism = par;
                let mut e = SimEngine::new(layout(), c);
                (0..6).map(|s| e.step(s)).collect()
            };
            let base = run(1);
            for par in [2usize, 4] {
                let wide = run(par);
                for (s, (a, b)) in base.iter().zip(&wide).enumerate() {
                    assert_reports_identical(&format!("{spec}/{}/par{par}", mode.name()), s, a, b);
                }
            }
        }
    }
}

#[test]
fn faulted_runs_are_reproducible_same_seed() {
    // `chaos --seed N` twice ⇒ byte-identical output, engine edition:
    // generated schedules replayed twice produce identical streams.
    for seed in [7u64, 11] {
        let mut p = ChaosPlan::generate(seed, 5, 8);
        p.mode = RecoveryMode::DropRescale;
        let run = || -> Vec<StepReport> {
            let mut e = SimEngine::new(layout(), cfg("iwp:layerwise", 5, TopoKind::Flat, Some(p.clone())));
            (0..8).map(|s| e.step(s)).collect()
        };
        let a = run();
        let b = run();
        for (s, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_reports_identical(&format!("seed{seed}"), s, x, y);
        }
    }
}

#[test]
fn uds_re_ring_matches_sim_on_every_topology_and_mode() {
    // A mid-run crash on every topology, both recovery modes: the
    // socket engine tears its ring down, re-rings the survivors, and
    // must still reproduce the virtual oracle bit for bit.
    with_watchdog("uds-re-ring", || {
        for mode in [RecoveryMode::Handoff, RecoveryMode::DropRescale] {
            for topo in topologies() {
                let ctx = format!("iwp:fixed/{}/{}", topo.name(), mode.name());
                let mut sim =
                    SimEngine::new(layout(), cfg("iwp:fixed", 5, topo, Some(plan(mode))));
                let mut c = cfg("iwp:fixed", 5, topo, Some(plan(mode)));
                c.transport = TransportKind::Uds;
                let mut wire = WireEngine::new(layout(), c)
                    .unwrap_or_else(|e| panic!("{ctx}: wire construction: {e}"));
                for s in 0..6 {
                    let a = sim.step(s);
                    let w = wire.step(s);
                    assert_reports_identical(&ctx, s, &a, &w.report);
                    assert!(w.real_bytes > 0, "{ctx} step {s}: no real bytes");
                }
                assert_eq!(wire.ring().n(), 5, "crash then join lands back on 5 ranks");
                wire.shutdown().unwrap_or_else(|e| panic!("{ctx}: shutdown: {e}"));
            }
        }
    });
}

#[test]
fn uds_re_ring_matches_sim_across_the_spec_sweep() {
    // Every bench pipeline through the full fault schedule on the flat
    // ring — per-node (DGC) and shared-mask state migration, ternary
    // encoders, and the dense baseline all re-ring deterministically.
    with_watchdog("uds-specs", || {
        for spec in step_specs() {
            let ctx = format!("{}/chaos", spec.name());
            let p = plan(RecoveryMode::Handoff);
            let mut sim = SimEngine::new(layout(), cfg(&spec.name(), 5, TopoKind::Flat, Some(p.clone())));
            let mut c = cfg(&spec.name(), 5, TopoKind::Flat, Some(p));
            c.transport = TransportKind::Uds;
            let mut wire = WireEngine::new(layout(), c)
                .unwrap_or_else(|e| panic!("{ctx}: wire construction: {e}"));
            for s in 0..6 {
                let a = sim.step(s);
                let w = wire.step(s);
                assert_reports_identical(&ctx, s, &a, &w.report);
            }
            wire.shutdown().unwrap_or_else(|e| panic!("{ctx}: shutdown: {e}"));
        }
    });
}
