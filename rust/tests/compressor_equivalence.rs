//! The compressor-subsystem golden suite (DESIGN.md §12).
//!
//! The refactor's contract: every legacy `Method` enum value, running
//! through its canonical `Compressor` spec, is **bit-identical** to the
//! pre-refactor engine. This file keeps an inline reimplementation of
//! the pre-refactor `SimEngine::step` match arms (built from the same
//! retained primitives — `fuse`, `ResidualStore`, `Dgc`, `TernGrad`,
//! the `Topology` accounting entry points) as the checked-in golden
//! oracle, and replays it against the trait-driven engine across
//! methods × topologies × ring sizes. The stage grammar's semantics
//! (`+nosel`, `+nomcorr`) are pinned against their config-knob
//! equivalents, and the new compositions cross-validate against the
//! `CostModel` byte/wire-time predictions bit for bit.

use ringiwp::compress::fuse;
use ringiwp::compress::importance::{LayerStats, EPS};
use ringiwp::compress::residual::ResidualStore;
use ringiwp::compress::terngrad::{TernBlob, TernGrad};
use ringiwp::compress::threshold::{ThresholdCfg, ThresholdPolicy};
use ringiwp::compress::{dgc::Dgc, Method, MethodSpec};
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::grad::SynthGrads;
use ringiwp::metrics::CompressionAccount;
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{CostModel, LinkSpec, RingNet, TopoKind};
use ringiwp::ring::Arena;
use ringiwp::sparse::{wire_bytes, BitMask, WireFormat};
use ringiwp::util::rng::Rng;

const SIM_NODE_CAP: usize = 4; // SimEngine::SIM_NODE_CAP

fn layout() -> ParamLayout {
    ParamLayout::new(
        "comp_eq",
        vec![
            ("conv1".into(), vec![24, 12, 3, 3], LayerKind::Conv),
            ("bn1".into(), vec![48], LayerKind::BatchNorm),
            ("fc".into(), vec![300, 10], LayerKind::Fc),
            ("bias".into(), vec![10], LayerKind::Bias),
        ],
    )
}

fn base_cfg(method: Method, nodes: usize, topology: TopoKind) -> SimCfg {
    SimCfg {
        nodes,
        method: method.spec(),
        topology,
        parallelism: 1,
        link: LinkSpec::gigabit_ethernet(),
        seed: 71,
        ..Default::default()
    }
}

type Reports = Vec<(u64, u64, u64)>;

fn engine_run(cfg: &SimCfg, steps: usize) -> (Reports, u64) {
    let mut engine = SimEngine::new(layout(), cfg.clone());
    let mut reports = Vec::new();
    for s in 0..steps {
        let r = engine.step(s);
        reports.push((r.wire_bytes_per_node, r.density.to_bits(), r.seconds.to_bits()));
    }
    (reports, engine.account.ratio().to_bits())
}

/// The pre-refactor `SimEngine::step`, reimplemented inline from the
/// retained primitives: the golden oracle the trait-driven engine must
/// reproduce bit for bit (sequential path; the executor contract is
/// pinned separately by the parallel/topology equivalence suites).
fn legacy_engine_run(cfg: &SimCfg, steps: usize) -> (Reports, u64) {
    let layout = layout();
    let total = layout.total_params();
    let nodes = cfg.nodes;
    let sim_nodes = nodes.min(SIM_NODE_CAP);
    let method = cfg.method.legacy().expect("legacy method");
    let synth = SynthGrads::new(layout.clone(), cfg.seed ^ 0x5EED);
    let mut root = Rng::new(cfg.seed);
    let mut rngs: Vec<Rng> = (0..nodes).map(|i| root.split(i as u64)).collect();
    let mut ctl_rng = root.split(0xC011);
    let mut stores: Vec<ResidualStore> = (0..sim_nodes)
        .map(|_| ResidualStore::new(total, cfg.momentum))
        .collect();
    let mut dgcs: Vec<Dgc> = (0..sim_nodes)
        .map(|_| Dgc::new(total, cfg.dgc_density, cfg.momentum))
        .collect();
    let policy = match method {
        Method::IwpLayerwise => ThresholdPolicy::Layerwise(ThresholdCfg {
            alpha: cfg.threshold,
            beta: cfg.beta,
            c: cfg.c,
            ..Default::default()
        }),
        _ => ThresholdPolicy::Fixed(cfg.threshold),
    };
    let topo = cfg.topology.build(nodes);
    let mut net = RingNet::new(nodes, cfg.link, 0.05);
    let mut arena = Arena::for_nodes(nodes);
    let exec = ringiwp::ring::Executor::sequential();
    let mut prev_stats = vec![LayerStats::default(); layout.n_layers()];
    let mut grads = vec![vec![0.0f32; total]; sim_nodes];
    let mut account = CompressionAccount::new();
    let dense_ref = 2 * (nodes as u64 - 1) * layout.dense_bytes() / nodes as u64;
    let mut reports = Vec::new();

    for step in 0..steps {
        let epoch = step / cfg.steps_per_epoch.max(1);
        let needed = match method {
            Method::Baseline => 0,
            Method::TernGrad => 1,
            _ => sim_nodes,
        };
        for node in 0..needed {
            synth.gen_step_node(step, node, &mut grads[node]);
            for v in grads[node].iter_mut() {
                *v *= 0.85 + 0.3 * rngs[node].uniform();
            }
        }
        let t0 = net.clock();
        let (wire, payload, density) = match method {
            Method::Baseline => {
                let rep = topo.dense_bytes_only(&mut net, total, &mut arena);
                (
                    rep.total_bytes() / nodes as u64,
                    layout.dense_bytes(),
                    1.0,
                )
            }
            Method::TernGrad => {
                let t = TernGrad::encode(&grads[0], &layout, &mut rngs[0]);
                let blob = t.wire_bytes();
                let rep = topo.spread_bytes(&mut net, blob, nodes, &mut arena);
                (rep.total_bytes() / nodes as u64, blob, 1.0)
            }
            Method::Dgc => {
                let d = Dgc::density_at_epoch(cfg.dgc_density, epoch, cfg.warmup_epochs);
                let k = ((total as f64) * d).ceil() as usize;
                let mut supports: Vec<BitMask> = Vec::new();
                for (node, dgc) in dgcs.iter_mut().enumerate() {
                    dgc.density = d;
                    let sv = dgc.step(&grads[node]);
                    let mut m = BitMask::zeros(total);
                    for &i in &sv.idx {
                        m.set(i as usize);
                    }
                    supports.push(m);
                }
                for rng in rngs[sim_nodes..].iter_mut() {
                    let mut m = BitMask::zeros(total);
                    for _ in 0..k {
                        m.set(rng.below(total));
                    }
                    supports.push(m);
                }
                let rep = topo.sparse_support(&mut net, &supports, &exec, &mut arena);
                let payload = wire_bytes(WireFormat::cheapest(total, k), total, k);
                (
                    rep.mean_bytes_per_node() as u64,
                    payload,
                    rep.density_per_hop.last().copied().unwrap_or(d),
                )
            }
            Method::IwpFixed | Method::IwpLayerwise => {
                let thrs = policy.layer_thresholds(&layout, &prev_stats, epoch, 1.0);
                let broadcasters =
                    ctl_rng.choose_distinct(sim_nodes, cfg.mask_nodes.min(sim_nodes));
                let mut masks: Vec<Option<BitMask>> = vec![None; sim_nodes];
                let mut stats: Vec<Vec<LayerStats>> = vec![Vec::new(); sim_nodes];
                let mut bcast_rngs: Vec<Option<Rng>> = vec![None; sim_nodes];
                for &b in &broadcasters {
                    bcast_rngs[b] = Some(rngs[b].clone());
                }
                // Node-index-order fan-out, exactly as the engine's
                // sequential executor visits the (store, scratch) pairs.
                for node in 0..sim_nodes {
                    if let Some(rng) = bcast_rngs[node].as_mut() {
                        let mut mask = BitMask::zeros(total);
                        let mut st = Vec::new();
                        fuse::score_select_compact(
                            &layout,
                            &thrs,
                            &synth.weights,
                            &grads[node],
                            EPS,
                            cfg.random_select,
                            rng,
                            &mut stores[node],
                            &mut mask,
                            &mut st,
                        );
                        masks[node] = Some(mask);
                        stats[node] = st;
                    } else {
                        stores[node].accumulate(&grads[node]);
                    }
                }
                for s in prev_stats.iter_mut() {
                    *s = LayerStats::default();
                }
                for &b in &broadcasters {
                    rngs[b] = bcast_rngs[b].take().unwrap();
                    for (li, st) in stats[b].iter().enumerate() {
                        prev_stats[li].merge(st);
                    }
                }
                let mask_refs: Vec<&BitMask> = broadcasters
                    .iter()
                    .map(|&b| masks[b].as_ref().unwrap())
                    .collect();
                let (shared, rep) = topo.masked_bytes_only(&mut net, &mask_refs, &mut arena);
                for store in stores.iter_mut() {
                    store.clear_masked(&shared);
                }
                let nnz = shared.count();
                let payload = wire_bytes(WireFormat::cheapest(total, nnz), total, nnz);
                (
                    rep.mean_bytes_per_node() as u64,
                    payload,
                    shared.density(),
                )
            }
        };
        net.advance(0.35);
        account.record_full(dense_ref, wire, layout.dense_bytes(), payload, density);
        reports.push((wire, density.to_bits(), (net.clock() - t0).to_bits()));
    }
    (reports, account.ratio().to_bits())
}

#[test]
fn legacy_methods_are_bit_identical_to_their_compressor_specs() {
    for topology in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
        for method in Method::all() {
            for nodes in [4usize, 9] {
                let cfg = base_cfg(method, nodes, topology);
                let (golden, golden_ratio) = legacy_engine_run(&cfg, 3);
                let (got, got_ratio) = engine_run(&cfg, 3);
                assert_eq!(
                    golden, got,
                    "{method:?} {} nodes={nodes}: step reports diverged from the \
                     pre-refactor golden",
                    topology.name()
                );
                assert_eq!(
                    golden_ratio, got_ratio,
                    "{method:?} {} nodes={nodes}: accounting diverged",
                    topology.name()
                );
            }
        }
    }
}

#[test]
fn canonical_spec_strings_are_pinned() {
    let table = [
        (Method::Baseline, "dense"),
        (Method::TernGrad, "terngrad"),
        (Method::IwpFixed, "iwp:fixed"),
        (Method::IwpLayerwise, "iwp:layerwise"),
        (Method::Dgc, "dgc:topk"),
    ];
    for (m, canon) in table {
        assert_eq!(m.spec().name(), canon);
        assert_eq!(MethodSpec::parse(canon).unwrap(), m.spec());
        // Legacy aliases parse to the same spec value.
        assert_eq!(MethodSpec::parse(m.name()).unwrap(), m.spec());
    }
}

#[test]
fn nosel_stage_equals_random_select_knob() {
    // `iwp:fixed+nosel` with the config knob on must equal plain
    // `iwp:fixed` with the knob off, bit for bit — the stage and the
    // knob are the same pipeline point.
    let mut with_stage = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_stage.method = MethodSpec::parse("iwp:fixed+nosel").unwrap();
    with_stage.random_select = true;
    let mut with_knob = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_knob.random_select = false;
    assert_eq!(engine_run(&with_stage, 3), engine_run(&with_knob, 3));
}

#[test]
fn nomcorr_stage_equals_zero_momentum_knob() {
    let mut with_stage = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_stage.method = MethodSpec::parse("iwp:fixed+nomcorr").unwrap();
    let mut with_knob = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_knob.momentum = 0.0;
    assert_eq!(engine_run(&with_stage, 3), engine_run(&with_knob, 3));
}

#[test]
fn warmup_stage_equals_warmup_knob() {
    let mut with_stage = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_stage.method = MethodSpec::parse("iwp:fixed+warmup:2").unwrap();
    with_stage.steps_per_epoch = 1;
    let mut with_knob = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    with_knob.warmup_epochs = 2;
    with_knob.steps_per_epoch = 1;
    assert_eq!(engine_run(&with_stage, 3), engine_run(&with_knob, 3));
}

#[test]
fn new_compositions_are_bit_identical_across_parallelism_and_topology() {
    for spec in ["iwp:vargate", "dgc:layerwise", "iwp:fixed+tern"] {
        for topology in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            let cfg = |w: usize| -> SimCfg {
                let mut c = base_cfg(Method::IwpFixed, 9, topology);
                c.method = MethodSpec::parse(spec).unwrap();
                c.parallelism = w;
                c
            };
            let seq = engine_run(&cfg(1), 3);
            for w in [2usize, 4] {
                assert_eq!(
                    seq,
                    engine_run(&cfg(w), 3),
                    "{spec} {} w={w}: §4 contract violated",
                    topology.name()
                );
            }
        }
    }
}

/// Wire bytes/time of the new compositions, cross-validated against the
/// closed-form `CostModel` — bit for bit on a fresh clock (step 0): the
/// masked transport prices `iwp:vargate` for free, and the two-spread
/// `+tern` stage prices through `masked_tern_*` (DESIGN.md §12).
#[test]
fn new_compositions_cross_validate_against_cost_model() {
    let lay = layout();
    let total = lay.total_params();
    for topology in [TopoKind::Flat, TopoKind::Hier { group: 4 }, TopoKind::Tree] {
        // -- variance-gated IWP over the masked transport -------------
        let mut cfg = base_cfg(Method::IwpFixed, 8, topology);
        cfg.method = MethodSpec::parse("iwp:vargate").unwrap();
        let model = CostModel::new(cfg.nodes, cfg.link);
        let k = cfg.mask_nodes.min(SIM_NODE_CAP);
        let mut engine = SimEngine::new(lay.clone(), cfg.clone());
        let r = engine.step(0);
        let support = r.support_nnz as usize;
        assert!(support > 0, "{}: nothing selected", topology.name());
        assert_eq!(
            model.topo_masked_seconds(topology, total, k, support).to_bits(),
            r.wire_seconds.to_bits(),
            "{}: vargate wire time drifted from the masked prediction",
            topology.name()
        );
        assert_eq!(
            model.topo_masked_total_bytes(topology, total, k, support),
            engine.net().total_bytes(),
            "{}: vargate wire bytes drifted",
            topology.name()
        );

        // -- ternary payload stage ------------------------------------
        let mut cfg = base_cfg(Method::IwpFixed, 8, topology);
        cfg.method = MethodSpec::parse("iwp:fixed+tern").unwrap();
        let mut engine = SimEngine::new(lay.clone(), cfg);
        let r = engine.step(0);
        let nnz = r.support_nnz as usize;
        assert!(nnz > 0);
        assert_eq!(
            model.masked_tern_seconds(topology, total, k, nnz).to_bits(),
            r.wire_seconds.to_bits(),
            "{}: +tern wire time drifted from the two-spread prediction",
            topology.name()
        );
        assert_eq!(
            model.masked_tern_total_bytes(topology, total, k, nnz),
            engine.net().total_bytes(),
            "{}: +tern wire bytes drifted",
            topology.name()
        );
        // The ternary payload is 2 bits/coord + scale, far below the
        // f32 sparse payload at the same support.
        assert!(r.wire_bytes_per_node > 0);
        assert!(
            TernBlob::wire_bytes_for(nnz)
                < wire_bytes(WireFormat::cheapest(total, nnz), total, nnz)
        );

        // -- dense stays priced for free too --------------------------
        let cfg = base_cfg(Method::Baseline, 8, topology);
        let mut engine = SimEngine::new(lay.clone(), cfg);
        let r = engine.step(0);
        assert_eq!(
            model.topo_dense_seconds(topology, total).to_bits(),
            r.wire_seconds.to_bits(),
            "{}: dense wire time drifted",
            topology.name()
        );
        assert_eq!(
            model.topo_dense_total_bytes(topology, total),
            engine.net().total_bytes()
        );
    }
}

#[test]
fn vargate_tightens_noisy_layers_relative_to_fixed() {
    // Once trailing stats exist (step >= 1), layers whose var/mean
    // exceeds the gate compress harder than under the fixed policy at
    // the same alpha — vargate can only select a subset coordinate-wise
    // (thr_vargate >= thr_fixed per layer under +nosel).
    let mut fixed = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    fixed.method = MethodSpec::parse("iwp:fixed+nosel").unwrap();
    let mut gated = base_cfg(Method::IwpFixed, 8, TopoKind::Flat);
    gated.method = MethodSpec::parse("iwp:vargate+nosel").unwrap();
    let run = |cfg: &SimCfg| -> f64 {
        let mut e = SimEngine::new(layout(), cfg.clone());
        let mut last = 0.0;
        for s in 0..3 {
            last = e.step(s).density;
        }
        last
    };
    let d_fixed = run(&fixed);
    let d_gated = run(&gated);
    assert!(
        d_gated <= d_fixed,
        "vargate must not select more than fixed at the same alpha: {d_gated} vs {d_fixed}"
    );
}
