//! Transport-equivalence oracle suite (DESIGN.md §13).
//!
//! The single-process simulator is the specification; the real socket
//! transport is the implementation under test. For every bench
//! pipeline × topology × ring size, a `WireEngine` running over
//! loopback sockets must produce `StepReport`s **bit-identical** to
//! `SimEngine` on the same seeds — the engines share every compute
//! path and differ only in whether traveling payloads cross real ring
//! edges, so any framing, codec, relay or epoch bug diverges the
//! reports and fails here.
//!
//! Every socket-touching test runs under a hard watchdog: a deadlocked
//! ring fails the test in bounded time instead of hanging the suite
//! (CI adds an outer `timeout` as the backstop).

use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use ringiwp::exp::bench::step_specs;
use ringiwp::exp::simrun::{SimCfg, SimEngine, StepReport, WireEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::wire::{peer, serve_rank, Frame, Kind, WireStream};
use ringiwp::net::{LinkSpec, TopoKind, TransportKind, WireError, WireRing};

/// Hard per-test deadline: generous next to the observed runtime,
/// tiny next to a hung socket read (whose own timeout is 30 s).
const WATCHDOG: Duration = Duration::from_secs(180);

/// Run `f` on its own thread and fail loudly if it outlives the
/// watchdog; panics inside `f` propagate to the harness unchanged.
fn with_watchdog<F>(label: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let worker = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            f();
            let _ = tx.send(());
        })
        .expect("spawn watchdog worker");
    match rx.recv_timeout(WATCHDOG) {
        Ok(()) | Err(RecvTimeoutError::Disconnected) => {
            if let Err(panic) = worker.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("{label}: still running after {WATCHDOG:?} — ring deadlock");
        }
    }
}

/// Small but structurally honest inventory: conv + batchnorm + fc, an
/// unaligned layer boundary, and a single-element bias layer (the
/// codec edge shape).
fn layout() -> ParamLayout {
    ParamLayout::new(
        "equiv",
        vec![
            ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn".into(), vec![67], LayerKind::BatchNorm),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
            ("bias".into(), vec![1], LayerKind::Bias),
        ],
    )
}

fn cfg(spec: &str, nodes: usize, topology: TopoKind, transport: TransportKind) -> SimCfg {
    SimCfg {
        nodes,
        method: ringiwp::compress::MethodSpec::parse(spec).expect("registry spec"),
        link: LinkSpec::new(1e9, 1e-5),
        topology,
        transport,
        wire_dir: None,
        seed: 42,
        ..Default::default()
    }
}

fn assert_reports_identical(ctx: &str, step: usize, a: &StepReport, b: &StepReport) {
    assert_eq!(
        a.wire_bytes_per_node, b.wire_bytes_per_node,
        "{ctx} step {step}: wire_bytes_per_node"
    );
    assert_eq!(a.support_nnz, b.support_nnz, "{ctx} step {step}: support_nnz");
    assert_eq!(
        a.density.to_bits(),
        b.density.to_bits(),
        "{ctx} step {step}: density ({} vs {})",
        a.density,
        b.density
    );
    assert_eq!(
        a.seconds.to_bits(),
        b.seconds.to_bits(),
        "{ctx} step {step}: seconds ({} vs {})",
        a.seconds,
        b.seconds
    );
    assert_eq!(
        a.wire_seconds.to_bits(),
        b.wire_seconds.to_bits(),
        "{ctx} step {step}: wire_seconds ({} vs {})",
        a.wire_seconds,
        b.wire_seconds
    );
}

/// The oracle check for one (spec, topology, ring size) cell: run both
/// engines `steps` steps and require bit-identical reports, matching
/// accounting, and a matching importance snapshot at the end.
fn assert_cell_equivalent(spec: &str, topology: TopoKind, n: usize, transport: TransportKind) {
    let ctx = format!("{spec}/{}/n{n}/{transport}", topology.name());
    let steps = 2;
    let mut sim = SimEngine::new(layout(), cfg(spec, n, topology, TransportKind::Sim));
    let mut wire = WireEngine::new(layout(), cfg(spec, n, topology, transport))
        .unwrap_or_else(|e| panic!("{ctx}: wire ring construction failed: {e}"));
    for s in 0..steps {
        let a = sim.step(s);
        let w = wire.step(s);
        assert_reports_identical(&ctx, s, &a, &w.report);
        assert!(
            w.real_bytes > 0,
            "{ctx} step {s}: no bytes crossed the real ring"
        );
        assert!(w.wall_seconds >= 0.0);
    }
    assert_eq!(
        sim.account.ratio().to_bits(),
        wire.sim().account.ratio().to_bits(),
        "{ctx}: compression ratio diverged"
    );
    let (imp_a, stats_a) = sim.importance_snapshot();
    let imp_a: Vec<u32> = imp_a.iter().map(|v| v.to_bits()).collect();
    let n_stats_a = stats_a.len();
    let (imp_b, stats_b) = wire.sim_mut().importance_snapshot();
    assert_eq!(n_stats_a, stats_b.len(), "{ctx}: stats arity");
    for (i, (a, b)) in imp_a.iter().zip(imp_b).enumerate() {
        assert_eq!(*a, b.to_bits(), "{ctx}: importance[{i}] diverged");
    }
    wire.shutdown().unwrap_or_else(|e| panic!("{ctx}: shutdown: {e}"));
}

fn matrix_over(topology: &'static str) {
    with_watchdog(topology, move || {
        let topo = TopoKind::parse(topology).unwrap();
        for spec in step_specs() {
            for n in [4usize, 9] {
                assert_cell_equivalent(&spec.name(), topo, n, TransportKind::Uds);
            }
        }
    });
}

// One test per topology so the matrix arms run concurrently under the
// default test harness and a failure names its topology directly.

#[test]
fn uds_matches_sim_on_flat_ring() {
    matrix_over("flat");
}

#[test]
fn uds_matches_sim_on_hierarchical_ring() {
    matrix_over("hier:4");
}

#[test]
fn uds_matches_sim_on_tree() {
    matrix_over("tree");
}

#[test]
fn uds_matches_sim_on_pipelined_ring() {
    matrix_over("pipeline:4:flat");
}

#[test]
fn uds_matches_sim_on_ternary_blob_composition() {
    // `iwp:fixed+tern` is the one pipeline whose wire path ships the
    // single-scale TernBlob (FLAG_TERN_BLOB); it is not in the bench
    // spec set, so cover it explicitly.
    with_watchdog("tern-blob", || {
        assert_cell_equivalent("iwp:fixed+tern", TopoKind::Flat, 4, TransportKind::Uds);
    });
}

#[test]
fn tcp_matches_sim_smoke() {
    // The TCP flavor shares every wire code path except the socket
    // constructor, so one (spec, size) smoke cell suffices.
    with_watchdog("tcp", || {
        assert_cell_equivalent("iwp:layerwise", TopoKind::Flat, 4, TransportKind::Tcp);
        assert_cell_equivalent("baseline", TopoKind::Flat, 4, TransportKind::Tcp);
    });
}

#[test]
fn external_serve_ranks_match_sim() {
    // The serve-mode wiring (`ringiwp serve --rank R` ⇄
    // `WireRing::connect_external`): real rendezvous through a
    // directory, ranks on their own threads standing in for separate
    // processes — same sockets, same handshake, same frames.
    with_watchdog("serve", || {
        let n = 4usize;
        let dir = std::env::temp_dir().join(format!("riwp-equiv-serve-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ranks: Vec<_> = (0..n as u16)
            .map(|r| {
                let dir = dir.clone();
                std::thread::Builder::new()
                    .name(format!("serve-rank-{r}"))
                    .spawn(move || serve_rank(&dir, r, n as u16, TransportKind::Uds, true))
                    .unwrap()
            })
            .collect();

        let mut wire_cfg = cfg("iwp:fixed", n, TopoKind::Flat, TransportKind::Uds);
        wire_cfg.wire_dir = Some(dir.clone());
        let mut sim = SimEngine::new(layout(), cfg("iwp:fixed", n, TopoKind::Flat, TransportKind::Sim));
        let mut wire = WireEngine::new(layout(), wire_cfg).expect("connect to serve ranks");
        for s in 0..2 {
            let a = sim.step(s);
            let w = wire.step(s);
            assert_reports_identical("serve/iwp:fixed/n4", s, &a, &w.report);
        }
        wire.shutdown().unwrap();
        for r in ranks {
            let sessions = r.join().expect("serve rank thread").expect("serve rank exit");
            assert_eq!(sessions, 1, "once-mode rank must serve exactly one session");
        }
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn version_bumped_frame_is_rejected_across_a_real_socket() {
    // The acceptance criterion at the socket layer: a peer speaking a
    // bumped protocol version is rejected with the typed error, read
    // off an actual Unix socket rather than an in-memory buffer.
    with_watchdog("version-skew", || {
        let (mut a, mut b) = WireStream::pair(TransportKind::Uds).unwrap();
        let mut bytes = Frame::new(Kind::Dense, 0, 1, 0, vec![0, 0, 0, 0]).encode();
        let bumped = ringiwp::net::wire::VERSION + 1;
        bytes[4..6].copy_from_slice(&bumped.to_le_bytes());
        std::io::Write::write_all(&mut a, &bytes).unwrap();
        std::io::Write::flush(&mut a).unwrap();
        match Frame::read_from(&mut b) {
            Err(WireError::Version { got, want }) => {
                assert_eq!(got, bumped);
                assert_eq!(want, ringiwp::net::wire::VERSION);
            }
            other => panic!("expected typed Version error, got {other:?}"),
        }
    });
}

#[test]
fn wire_real_seconds_and_bytes_sit_next_to_virtual_accounting() {
    // EXPERIMENTS.md §10's measurement contract: the wire engine
    // reports real wall seconds and real (header-inclusive) bytes
    // alongside the untouched virtual prediction — real bytes must
    // exceed the virtual payload bytes it frames.
    with_watchdog("real-vs-virtual", || {
        let mut wire =
            WireEngine::new(layout(), cfg("baseline", 4, TopoKind::Flat, TransportKind::Uds))
                .unwrap();
        let w = wire.step(0);
        assert!(w.report.wire_seconds > 0.0, "virtual prediction present");
        assert!(w.wall_seconds > 0.0, "real clock present");
        assert!(
            w.real_bytes > w.report.wire_bytes_per_node,
            "real bytes ({}) must exceed one node's virtual payload ({})",
            w.real_bytes,
            w.report.wire_bytes_per_node
        );
        wire.shutdown().unwrap();
    });
}

// ---- failure modes (DESIGN.md §15) -------------------------------------

#[test]
fn mid_frame_peer_death_is_a_typed_error_not_a_hang() {
    // A rank crashing partway through a frame write: the survivor's
    // next read off the real socket must come back as the typed
    // `WireError::Io` UnexpectedEof — cut inside the header and inside
    // the payload both — never a hang or a partially-decoded frame.
    with_watchdog("mid-frame-death", || {
        let full = Frame::new(Kind::Dense, 0, 1, 0, vec![0xAB; 64]).encode();
        for cut in [7usize, full.len() - 16] {
            let (mut a, mut b) = WireStream::pair(TransportKind::Uds).unwrap();
            std::io::Write::write_all(&mut a, &full[..cut]).unwrap();
            std::io::Write::flush(&mut a).unwrap();
            drop(a); // the peer dies mid-frame
            match Frame::read_from(&mut b) {
                Err(WireError::Io(e)) => assert_eq!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof,
                    "cut at {cut}/{}",
                    full.len()
                ),
                other => panic!("cut at {cut}: expected typed Io error, got {other:?}"),
            }
        }
    });
}

#[test]
fn partition_detection_budget_is_pinned_and_overridable() {
    // The documented failure-detection budget: both wire timeouts sit
    // at 30 s (DESIGN.md §13). Changing either is a protocol decision —
    // this pin makes it a deliberate one.
    assert_eq!(peer::READ_TIMEOUT, Duration::from_secs(30));
    assert_eq!(peer::CONNECT_TIMEOUT, Duration::from_secs(30));
    with_watchdog("partition", || {
        // A partitioned peer: connected, alive, but never sends. With
        // the timeout shortened through the override seam, the
        // survivor's read returns typed within the budget instead of
        // deadlocking — the property the chaos harness leans on.
        let (a, mut b) = WireStream::pair(TransportKind::Uds).unwrap();
        b.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let start = Instant::now();
        match Frame::read_from(&mut b) {
            Err(WireError::Io(e)) => assert!(
                matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ),
                "unexpected error kind {:?}",
                e.kind()
            ),
            other => panic!("expected typed Io timeout, got {other:?}"),
        }
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "partition detection took {:?} — not bounded by the override",
            start.elapsed()
        );
        drop(a);
    });
}

#[test]
fn read_timeout_seam_arms_a_live_ring_without_perturbing_it() {
    // `WireRing::set_read_timeout` reaches every delivery socket: a
    // healthy ring still completes its exchanges with a 250 ms budget
    // armed (loopback is far faster), and restoring the default leaves
    // the ring shut-downable. Guards the seam the failure tests and
    // chaos runs use against silently arming only some readers.
    with_watchdog("ring-timeout", || {
        let links = vec![LinkSpec::new(1e9, 0.0); 3];
        let mut ring = WireRing::new_in_process(TransportKind::Uds, links).unwrap();
        ring.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        ring.begin_step(0);
        let v: Vec<f32> = (0..19).map(|i| i as f32 * 0.25 - 2.0).collect();
        assert_eq!(ring.exchange_dense(&v).unwrap(), 19);
        ring.set_read_timeout(Some(peer::READ_TIMEOUT)).unwrap();
        ring.shutdown().unwrap();
    });
}

#[test]
fn shutdown_is_idempotent_and_survives_a_dead_peer() {
    with_watchdog("shutdown-idempotent", || {
        // Double shutdown on a healthy ring is a no-op, not an error —
        // `WireEngine` re-rings by shutting down mid-run and its Drop
        // fires shutdown again at the end.
        let mut ring =
            WireRing::new_in_process(TransportKind::Uds, vec![LinkSpec::new(1e9, 0.0); 3])
                .unwrap();
        ring.shutdown().unwrap();
        ring.shutdown().unwrap();
        // Sending Shutdown toward a relay whose reader already died:
        // the write returns promptly — Ok while the kernel buffers,
        // or the typed hangup once it notices — never a panic (Rust
        // masks SIGPIPE) and never a hang. Repeating it is harmless.
        let (mut a, b) = WireStream::pair(TransportKind::Uds).unwrap();
        drop(b);
        let bytes = Frame::new(Kind::Shutdown, 0, 0, 0, Vec::new()).encode();
        for attempt in 0..2 {
            let r = std::io::Write::write_all(&mut a, &bytes)
                .and_then(|_| std::io::Write::flush(&mut a));
            if let Err(e) = r {
                assert!(
                    matches!(
                        e.kind(),
                        std::io::ErrorKind::BrokenPipe | std::io::ErrorKind::ConnectionReset
                    ),
                    "attempt {attempt}: unexpected error kind {:?}",
                    e.kind()
                );
            }
        }
    });
}
