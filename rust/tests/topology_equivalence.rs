//! The topology subsystem's contract (DESIGN.md §10): every topology —
//! flat ring, hierarchical ring, binomial tree — produces the same
//! reduced gradients as the sequential flat-ring oracle for every
//! schedule and parallelism level, its accounting-only paths reproduce
//! its exact paths' bytes and clocks bit for bit, and the closed-form
//! `CostModel::topo_*` predictions equal the simulation to the last
//! bit.
//!
//! Cross-topology value equality is checked on **integer-valued**
//! payloads: different topologies sum in different orders, and f32
//! addition only reassociates exactly on exactly-representable values.
//! (Small-magnitude integers are closed under the sums these tests
//! produce, so any correct reduce must agree bitwise.) Per-topology
//! parallel-vs-sequential equality — the DESIGN.md §4 contract — is
//! checked on arbitrary normal floats, where it must hold bit-for-bit
//! regardless of representability.

use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{CostModel, LinkSpec, PipeInner, RingNet, TopoKind, Topology};
use ringiwp::ring::{self, Arena, Executor, ReduceReport};
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::rng::Rng;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::gigabit_ethernet(), 0.05)
}

fn link() -> LinkSpec {
    LinkSpec::gigabit_ethernet()
}

/// Every kind the suite sweeps; hier group sizes cover divisible,
/// ragged, and degenerate (group 1 == flat) geometries.
fn kinds() -> Vec<TopoKind> {
    vec![
        TopoKind::Flat,
        TopoKind::Hier { group: 1 },
        TopoKind::Hier { group: 3 },
        TopoKind::Hier { group: 4 },
        TopoKind::Tree,
    ]
}

const RING_SIZES: [usize; 3] = [4, 8, 9];
const WORKERS: [usize; 3] = [1, 2, 4];

/// Integer-valued f32 buffers: sums stay exactly representable, so
/// every topology's reduce must agree bitwise with the flat oracle.
fn int_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(17) as f32 - 8.0).collect())
        .collect()
}

fn int_sparse(rng: &mut Rng, n: usize, len: usize, density: f64) -> Vec<SparseVec> {
    (0..n)
        .map(|_| {
            let mut dense = vec![0.0f32; len];
            for v in dense.iter_mut() {
                if (rng.uniform() as f64) < density {
                    *v = rng.below(15) as f32 - 7.0;
                }
            }
            SparseVec::from_dense(&dense)
        })
        .collect()
}

fn random_supports(rng: &mut Rng, n: usize, len: usize, sets: usize) -> Vec<BitMask> {
    (0..n)
        .map(|_| {
            let mut m = BitMask::zeros(len);
            for _ in 0..sets {
                m.set(rng.below(len));
            }
            m
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_reports_identical(a: &ReduceReport, b: &ReduceReport, ctx: &str) {
    assert_eq!(a.bytes_per_node, b.bytes_per_node, "{ctx}: bytes");
    assert_eq!(
        a.seconds.to_bits(),
        b.seconds.to_bits(),
        "{ctx}: seconds {} vs {}",
        a.seconds,
        b.seconds
    );
    let db = |r: &ReduceReport| -> Vec<u64> {
        r.density_per_hop.iter().map(|d| d.to_bits()).collect()
    };
    assert_eq!(db(a), db(b), "{ctx}: density_per_hop");
}

// ---- cross-topology value equality (integer oracle) --------------------

#[test]
fn dense_every_topology_matches_flat_oracle_bitwise() {
    for n in RING_SIZES {
        let len = 3001;
        let mut rng = Rng::new(100 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let mut net_o = net(n);
        let mut oracle = base.clone();
        ring::dense::allreduce(&mut net_o, &mut oracle);
        for kind in kinds() {
            let topo = kind.build(n);
            for w in WORKERS {
                let mut nw = net(n);
                let mut bufs = base.clone();
                let rep =
                    topo.dense(&mut nw, &mut bufs, &Executor::new(w), &mut Arena::for_nodes(n));
                for (node, (o, b)) in oracle.iter().zip(&bufs).enumerate() {
                    assert_eq!(
                        bits(o),
                        bits(b),
                        "dense {} n={n} w={w} node={node}",
                        kind.name()
                    );
                }
                assert_eq!(rep.total_bytes(), nw.total_bytes(), "{}", kind.name());
            }
        }
    }
}

#[test]
fn sparse_every_topology_matches_flat_oracle_bitwise() {
    for n in RING_SIZES {
        let len = 2400;
        let mut rng = Rng::new(200 + n as u64);
        let inputs = int_sparse(&mut rng, n, len, 0.05);
        let mut net_o = net(n);
        let (oracle, _) = ring::sparse::allreduce(&mut net_o, &inputs);
        for kind in kinds() {
            let topo = kind.build(n);
            for w in WORKERS {
                let mut nw = net(n);
                let (got, rep) =
                    topo.sparse(&mut nw, &inputs, &Executor::new(w), &mut Arena::for_nodes(n));
                assert_eq!(bits(&oracle), bits(&got), "sparse {} n={n} w={w}", kind.name());
                assert_eq!(
                    rep.density_per_hop.len(),
                    topo.reduce_hops(),
                    "sparse {} n={n}: hop count",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn masked_every_topology_matches_flat_oracle_bitwise() {
    for n in RING_SIZES {
        let len = 2000;
        let mut rng = Rng::new(300 + n as u64);
        let mut mask_a = BitMask::zeros(len);
        let mut mask_b = BitMask::zeros(len);
        for _ in 0..120 {
            mask_a.set(rng.below(len));
            mask_b.set(rng.below(len));
        }
        let values = int_bufs(&mut rng, n, len);
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let mut net_o = net(n);
        let (shared_o, summed_o, _) =
            ring::masked::allreduce(&mut net_o, &[&mask_a, &mask_b], &refs);
        for kind in kinds() {
            let topo = kind.build(n);
            for w in WORKERS {
                let mut nw = net(n);
                let (shared, summed, rep) = topo.masked(
                    &mut nw,
                    &[&mask_a, &mask_b],
                    &refs,
                    &Executor::new(w),
                    &mut Arena::for_nodes(n),
                );
                assert_eq!(shared_o, shared, "masked {} n={n} w={w}: mask", kind.name());
                assert_eq!(
                    bits(&summed_o),
                    bits(&summed),
                    "masked {} n={n} w={w}: summed",
                    kind.name()
                );
                assert_eq!(rep.density_per_hop.len(), topo.reduce_hops());
            }
        }
    }
}

#[test]
fn support_final_density_is_the_union_on_every_topology() {
    // After a full reduce the travelling payloads carry the union of
    // every node's support, whatever path the chunks took — the final
    // density must equal the union's density exactly.
    for n in [6usize, 8, 9] {
        let len = 50_000;
        let mut rng = Rng::new(400 + n as u64);
        let supports = random_supports(&mut rng, n, len, 400);
        let mut union = BitMask::zeros(len);
        for s in &supports {
            union.or_assign(s);
        }
        let expect = union.count() as f64 / len as f64;
        for kind in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            let topo = kind.build(n);
            let mut nw = net(n);
            let rep = topo.sparse_support(
                &mut nw,
                &supports,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let last = *rep.density_per_hop.last().unwrap();
            assert_eq!(
                last.to_bits(),
                expect.to_bits(),
                "{} n={n}: final density {last} vs union {expect}",
                kind.name()
            );
        }
    }
}

#[test]
fn support_union_survives_degenerate_aligned_chunks() {
    // More leader groups than 64-bit mask words (n=6, group=2 -> 3
    // leader chunks over a 2-word mask): the aligned partition's
    // trailing chunk collapses to the unaligned `len..len`, which must
    // slice to an empty word window, not a phantom overlap.
    let (n, len) = (6usize, 100usize);
    let mut rng = Rng::new(414);
    let supports = random_supports(&mut rng, n, len, 20);
    let mut union = BitMask::zeros(len);
    for s in &supports {
        union.or_assign(s);
    }
    let expect = union.count() as f64 / len as f64;
    for kind in [TopoKind::Hier { group: 2 }, TopoKind::Hier { group: 4 }, TopoKind::Tree] {
        let topo = kind.build(n);
        let mut nw = net(n);
        let rep = topo.sparse_support(
            &mut nw,
            &supports,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        let last = *rep.density_per_hop.last().unwrap();
        assert_eq!(
            last.to_bits(),
            expect.to_bits(),
            "{}: final density {last} vs union {expect}",
            kind.name()
        );
    }
}

// ---- per-topology parallel determinism (arbitrary floats) --------------

#[test]
fn parallel_is_bit_identical_per_topology_on_normal_floats() {
    for n in [6usize, 9] {
        let len = 2000;
        let mut rng = Rng::new(500 + n as u64);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let inputs = int_sparse(&mut rng, n, len, 0.05); // reuse, any values fine
        for kind in kinds() {
            let topo = kind.build(n);
            let mut net_s = net(n);
            let mut bufs_s = base.clone();
            let rep_s = topo.dense(
                &mut net_s,
                &mut bufs_s,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_sp = net(n);
            let (sum_s, rep_sp) = topo.sparse(
                &mut net_sp,
                &inputs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            for w in [2usize, 4] {
                let mut net_p = net(n);
                let mut bufs_p = base.clone();
                let rep_p = topo.dense(
                    &mut net_p,
                    &mut bufs_p,
                    &Executor::new(w),
                    &mut Arena::for_nodes(n),
                );
                assert_reports_identical(&rep_s, &rep_p, &format!("dense {} w={w}", kind.name()));
                for (s, p) in bufs_s.iter().zip(&bufs_p) {
                    assert_eq!(bits(s), bits(p), "dense {} w={w}", kind.name());
                }
                let mut net_pp = net(n);
                let (sum_p, rep_pp) = topo.sparse(
                    &mut net_pp,
                    &inputs,
                    &Executor::new(w),
                    &mut Arena::for_nodes(n),
                );
                let ctx = format!("sparse {} w={w}", kind.name());
                assert_reports_identical(&rep_sp, &rep_pp, &ctx);
                assert_eq!(bits(&sum_s), bits(&sum_p), "sparse {} w={w}", kind.name());
            }
        }
    }
}

// ---- accounting-only paths vs exact paths ------------------------------

#[test]
fn bytes_only_paths_match_exact_paths_per_topology() {
    for n in RING_SIZES {
        let len = 2000;
        let mut rng = Rng::new(600 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let mut mask = BitMask::zeros(len);
        for _ in 0..150 {
            mask.set(rng.below(len));
        }
        let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
        for kind in kinds() {
            let topo = kind.build(n);
            // dense
            let mut net_a = net(n);
            let mut bufs = base.clone();
            let rep_a = topo.dense(
                &mut net_a,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_b = net(n);
            let rep_b = topo.dense_bytes_only(&mut net_b, len, &mut Arena::for_nodes(n));
            assert_eq!(rep_a.bytes_per_node, rep_b.bytes_per_node, "{} dense", kind.name());
            assert_eq!(rep_a.seconds.to_bits(), rep_b.seconds.to_bits());
            assert_eq!(net_a.rounds(), net_b.rounds());
            // masked
            let mut net_c = net(n);
            let (shared_c, _, rep_c) = topo.masked(
                &mut net_c,
                &[&mask],
                &refs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_d = net(n);
            let (shared_d, rep_d) =
                topo.masked_bytes_only(&mut net_d, &[&mask], &mut Arena::for_nodes(n));
            assert_eq!(shared_c, shared_d, "{} masked mask", kind.name());
            assert_eq!(rep_c.total_bytes(), rep_d.total_bytes(), "{} masked", kind.name());
            assert_eq!(rep_c.seconds.to_bits(), rep_d.seconds.to_bits());
        }
    }
}

// ---- closed-form cost model cross-validation ---------------------------

#[test]
fn cost_model_matches_simulation_bit_for_bit_per_topology() {
    for n in RING_SIZES {
        let len = 2500;
        let model = CostModel::new(n, link());
        let mut rng = Rng::new(700 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let mut mask = BitMask::zeros(len);
        for _ in 0..200 {
            mask.set(rng.below(len));
        }
        let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
        let support = mask.count();
        for kind in kinds() {
            let topo = kind.build(n);
            let ctx = format!("{} n={n}", kind.name());
            // dense: bytes and virtual seconds, bit for bit.
            let mut nw = net(n);
            let mut bufs = base.clone();
            let rep = topo.dense(
                &mut nw,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            assert_eq!(model.topo_dense_total_bytes(kind, len), rep.total_bytes(), "{ctx}");
            assert_eq!(
                model.topo_dense_seconds(kind, len).to_bits(),
                rep.seconds.to_bits(),
                "{ctx}: dense {} vs {}",
                model.topo_dense_seconds(kind, len),
                rep.seconds
            );
            // masked: spread + compacted dense, accumulated in clock order.
            let mut nw = net(n);
            let (_, _, rep) = topo.masked(
                &mut nw,
                &[&mask],
                &refs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            assert_eq!(
                model.topo_masked_total_bytes(kind, len, 1, support),
                rep.total_bytes(),
                "{ctx}: masked bytes"
            );
            assert_eq!(
                model.topo_masked_seconds(kind, len, 1, support).to_bits(),
                rep.seconds.to_bits(),
                "{ctx}: masked seconds"
            );
            // blob spread.
            for k in [1usize, 3, n] {
                let mut nw = net(n);
                let rep = topo.spread_bytes(&mut nw, 777, k, &mut Arena::for_nodes(n));
                assert_eq!(
                    model.topo_spread_total_bytes(kind, 777, k),
                    rep.total_bytes(),
                    "{ctx}: spread k={k}"
                );
                assert_eq!(
                    model.topo_spread_seconds(kind, 777, k).to_bits(),
                    rep.seconds.to_bits(),
                    "{ctx}: spread seconds k={k}"
                );
            }
        }
    }
}

#[test]
fn hier_group_one_degenerates_to_the_flat_ring() {
    for n in [4usize, 7, 8] {
        let len = 1800;
        let mut rng = Rng::new(800 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let flat = TopoKind::Flat.build(n);
        let hier1 = TopoKind::Hier { group: 1 }.build(n);
        let mut net_f = net(n);
        let mut bufs_f = base.clone();
        let rep_f = flat.dense(
            &mut net_f,
            &mut bufs_f,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        let mut net_h = net(n);
        let mut bufs_h = base;
        let rep_h = hier1.dense(
            &mut net_h,
            &mut bufs_h,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        assert_eq!(rep_f.bytes_per_node, rep_h.bytes_per_node, "n={n}");
        assert_eq!(rep_f.seconds.to_bits(), rep_h.seconds.to_bits(), "n={n}");
        assert_eq!(net_f.rounds(), net_h.rounds(), "n={n}");
        for (f, h) in bufs_f.iter().zip(&bufs_h) {
            assert_eq!(bits(f), bits(h), "n={n}: values");
        }
    }
}

// ---- the layer-pipelined wrapper (DESIGN.md §11) -----------------------

/// Pipeline variants the dedicated sweeps cover: every base topology,
/// serial (`chunks = 1`) and genuinely chunked.
fn pipeline_kinds() -> Vec<TopoKind> {
    let mut out = Vec::new();
    for inner in [PipeInner::Flat, PipeInner::Hier { group: 3 }, PipeInner::Tree] {
        for chunks in [1usize, 3] {
            out.push(TopoKind::Pipeline { chunks, inner });
        }
    }
    out
}

#[test]
fn pipeline_values_match_wrapped_topology_bitwise() {
    // The §11 contract: `pipeline:<k>` reduces to the same values as its
    // wrapped topology on exactly-representable payloads (per-chunk sums
    // add the same node values per coordinate), at every parallelism.
    for n in [6usize, 9] {
        let len = 2003;
        let mut rng = Rng::new(900 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let mut mask_a = BitMask::zeros(len);
        let mut mask_b = BitMask::zeros(len);
        for _ in 0..150 {
            mask_a.set(rng.below(len));
            mask_b.set(rng.below(len));
        }
        let values = int_bufs(&mut rng, n, len);
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let inputs = int_sparse(&mut rng, n, len, 0.05);
        for kind in pipeline_kinds() {
            let TopoKind::Pipeline { inner, .. } = kind else {
                unreachable!()
            };
            let wrapped = inner.kind().build(n);
            let pipe = kind.build(n);
            // Wrapped-topology oracles (sequential).
            let mut net_w = net(n);
            let mut bufs_w = base.clone();
            wrapped.dense(
                &mut net_w,
                &mut bufs_w,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_m = net(n);
            let (shared_w, summed_w, _) = wrapped.masked(
                &mut net_m,
                &[&mask_a, &mask_b],
                &refs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_s = net(n);
            let (sum_w, rep_sw) = wrapped.sparse(
                &mut net_s,
                &inputs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            for w in WORKERS {
                let ctx = format!("{} n={n} w={w}", kind.name());
                let mut nw = net(n);
                let mut bufs = base.clone();
                pipe.dense(&mut nw, &mut bufs, &Executor::new(w), &mut Arena::for_nodes(n));
                for (a, b) in bufs_w.iter().zip(&bufs) {
                    assert_eq!(bits(a), bits(b), "{ctx}: dense values");
                }
                let mut nw = net(n);
                let (shared, summed, rep) = pipe.masked(
                    &mut nw,
                    &[&mask_a, &mask_b],
                    &refs,
                    &Executor::new(w),
                    &mut Arena::for_nodes(n),
                );
                assert_eq!(shared_w, shared, "{ctx}: shared mask");
                assert_eq!(bits(&summed_w), bits(&summed), "{ctx}: masked sums");
                assert_eq!(rep.density_per_hop.len(), pipe.reduce_hops(), "{ctx}");
                // Per-node-support schedules delegate verbatim.
                let mut nw = net(n);
                let (sum_p, rep_sp) = pipe.sparse(
                    &mut nw,
                    &inputs,
                    &Executor::new(w),
                    &mut Arena::for_nodes(n),
                );
                assert_eq!(bits(&sum_w), bits(&sum_p), "{ctx}: sparse sums");
                assert_eq!(rep_sw.bytes_per_node, rep_sp.bytes_per_node, "{ctx}: sparse bytes");
            }
        }
    }
}

#[test]
fn pipeline_bytes_only_and_spread_match_exact_paths() {
    for n in [5usize, 8] {
        let len = 3000;
        let mut rng = Rng::new(950 + n as u64);
        let base = int_bufs(&mut rng, n, len);
        let mut mask = BitMask::zeros(len);
        for _ in 0..200 {
            mask.set(rng.below(len));
        }
        let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
        for kind in pipeline_kinds() {
            let TopoKind::Pipeline { inner, .. } = kind else {
                unreachable!()
            };
            let pipe = kind.build(n);
            let ctx = format!("{} n={n}", kind.name());
            // dense
            let mut net_a = net(n);
            let mut bufs = base.clone();
            let rep_a = pipe.dense(
                &mut net_a,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_b = net(n);
            let rep_b = pipe.dense_bytes_only(&mut net_b, len, &mut Arena::for_nodes(n));
            assert_eq!(rep_a.bytes_per_node, rep_b.bytes_per_node, "{ctx}: dense");
            assert_eq!(rep_a.seconds.to_bits(), rep_b.seconds.to_bits(), "{ctx}");
            assert_eq!(net_a.rounds(), net_b.rounds(), "{ctx}");
            // masked
            let mut net_c = net(n);
            let (shared_c, _, rep_c) = pipe.masked(
                &mut net_c,
                &[&mask],
                &refs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_d = net(n);
            let (shared_d, rep_d) =
                pipe.masked_bytes_only(&mut net_d, &[&mask], &mut Arena::for_nodes(n));
            assert_eq!(shared_c, shared_d, "{ctx}: masked mask");
            assert_eq!(rep_c.bytes_per_node, rep_d.bytes_per_node, "{ctx}: masked");
            assert_eq!(rep_c.seconds.to_bits(), rep_d.seconds.to_bits(), "{ctx}");
            // blob spread delegates to the wrapped topology verbatim.
            let wrapped = inner.kind().build(n);
            for k in [1usize, 3] {
                let mut net_e = net(n);
                let rep_e = pipe.spread_bytes(&mut net_e, 777, k, &mut Arena::for_nodes(n));
                let mut net_f = net(n);
                let rep_f = wrapped.spread_bytes(&mut net_f, 777, k, &mut Arena::for_nodes(n));
                assert_eq!(rep_e.bytes_per_node, rep_f.bytes_per_node, "{ctx} k={k}");
                assert_eq!(rep_e.seconds.to_bits(), rep_f.seconds.to_bits(), "{ctx} k={k}");
            }
        }
    }
}

#[test]
fn pipeline_schedules_have_zero_steady_state_reallocations() {
    let n = 8;
    let len = 4000;
    let mut rng = Rng::new(57);
    let base = int_bufs(&mut rng, n, len);
    let mut mask = BitMask::zeros(len);
    for _ in 0..200 {
        mask.set(rng.below(len));
    }
    let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
    let exec = Executor::sequential();
    for kind in [
        TopoKind::Pipeline {
            chunks: 4,
            inner: PipeInner::Flat,
        },
        TopoKind::Pipeline {
            chunks: 3,
            inner: PipeInner::Hier { group: 3 },
        },
    ] {
        let topo = kind.build(n);
        let mut arena = Arena::for_nodes(n);
        let run_all = |arena: &mut Arena| {
            let mut nw = net(n);
            let mut bufs = base.clone();
            topo.dense(&mut nw, &mut bufs, &exec, arena);
            let mut nw = net(n);
            topo.dense_bytes_only(&mut nw, len, arena);
            let mut nw = net(n);
            topo.masked(&mut nw, &[&mask], &refs, &exec, arena);
            let mut nw = net(n);
            topo.masked_bytes_only(&mut nw, &[&mask], arena);
            let mut nw = net(n);
            topo.spread_bytes(&mut nw, 999, 3, arena);
        };
        run_all(&mut arena); // warm-up
        let warm = arena.grows();
        assert!(warm > 0, "{}: warm-up must populate the arena", kind.name());
        for pass in 0..3 {
            run_all(&mut arena);
            assert_eq!(
                arena.grows(),
                warm,
                "{}: steady-state pass {pass} reallocated",
                kind.name()
            );
        }
    }
}

// ---- arena zero-alloc steady state on the new paths --------------------

#[test]
fn topology_schedules_have_zero_steady_state_reallocations() {
    let n = 9;
    let len = 4000;
    let mut rng = Rng::new(53);
    let base = int_bufs(&mut rng, n, len);
    let inputs = int_sparse(&mut rng, n, len, 0.02);
    let supports = random_supports(&mut rng, n, len, 100);
    let mut mask = BitMask::zeros(len);
    for _ in 0..200 {
        mask.set(rng.below(len));
    }
    let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
    let exec = Executor::sequential();
    for kind in [TopoKind::Hier { group: 4 }, TopoKind::Tree] {
        let topo = kind.build(n);
        let mut arena = Arena::for_nodes(n);
        let run_all = |arena: &mut Arena| {
            let mut nw = net(n);
            let mut bufs = base.clone();
            topo.dense(&mut nw, &mut bufs, &exec, arena);
            let mut nw = net(n);
            topo.dense_bytes_only(&mut nw, len, arena);
            let mut nw = net(n);
            topo.sparse(&mut nw, &inputs, &exec, arena);
            let mut nw = net(n);
            topo.sparse_support(&mut nw, &supports, &exec, arena);
            let mut nw = net(n);
            topo.masked(&mut nw, &[&mask], &refs, &exec, arena);
            let mut nw = net(n);
            topo.masked_bytes_only(&mut nw, &[&mask], arena);
            let mut nw = net(n);
            topo.spread_bytes(&mut nw, 999, 3, arena);
        };
        run_all(&mut arena); // warm-up
        let warm = arena.grows();
        assert!(warm > 0, "{}: warm-up must populate the arena", kind.name());
        for pass in 0..3 {
            run_all(&mut arena);
            assert_eq!(
                arena.grows(),
                warm,
                "{}: steady-state pass {pass} reallocated",
                kind.name()
            );
        }
    }
}

// ---- engine-level equivalence across topologies ------------------------

fn sim_layout() -> ParamLayout {
    ParamLayout::new(
        "topo_eq",
        vec![
            ("conv1".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn1".into(), vec![64], LayerKind::BatchNorm),
            ("fc".into(), vec![512, 10], LayerKind::Fc),
        ],
    )
}

fn run_engine(
    method: Method,
    nodes: usize,
    parallelism: usize,
    topology: TopoKind,
) -> (Vec<(u64, u64, u64)>, f64) {
    let cfg = SimCfg {
        nodes,
        method: method.spec(),
        parallelism,
        topology,
        link: LinkSpec::gigabit_ethernet(),
        seed: 23,
        ..Default::default()
    };
    let mut engine = SimEngine::new(sim_layout(), cfg);
    let mut reports = Vec::new();
    for s in 0..3 {
        let r = engine.step(s);
        reports.push((r.wire_bytes_per_node, r.density.to_bits(), r.seconds.to_bits()));
    }
    (reports, engine.account.ratio())
}

#[test]
fn sim_engine_is_bit_identical_across_parallelism_on_every_topology() {
    for topology in [
        TopoKind::Hier { group: 3 },
        TopoKind::Tree,
        TopoKind::Pipeline {
            chunks: 3,
            inner: PipeInner::Flat,
        },
    ] {
        for method in [
            Method::Baseline,
            Method::TernGrad,
            Method::Dgc,
            Method::IwpFixed,
            Method::IwpLayerwise,
        ] {
            for nodes in [4usize, 9] {
                let (seq_reports, seq_ratio) = run_engine(method, nodes, 1, topology);
                for w in [2usize, 4] {
                    let (par_reports, par_ratio) = run_engine(method, nodes, w, topology);
                    assert_eq!(
                        seq_reports, par_reports,
                        "{method:?} {} nodes={nodes} w={w}: step reports diverged",
                        topology.name()
                    );
                    assert_eq!(
                        seq_ratio.to_bits(),
                        par_ratio.to_bits(),
                        "{method:?} {} nodes={nodes} w={w}: ratio diverged",
                        topology.name()
                    );
                }
            }
        }
    }
}

#[test]
fn sim_engine_flat_topology_equals_legacy_default() {
    // `--topology flat` must be bit-identical to the pre-topology
    // engine; the legacy default IS flat, so explicit-flat and default
    // runs must produce identical step reports. When the environment
    // overrides the default topology (RINGIWP_TOPOLOGY), defaults are
    // deliberately non-flat — skip rather than fail the contract check.
    if std::env::var("RINGIWP_TOPOLOGY").is_ok() {
        eprintln!("SKIP (RINGIWP_TOPOLOGY overrides the default topology)");
        return;
    }
    for method in [Method::Baseline, Method::TernGrad, Method::Dgc, Method::IwpFixed] {
        let (explicit, er) = run_engine(method, 8, 1, TopoKind::Flat);
        let cfg = SimCfg {
            nodes: 8,
            method: method.spec(),
            parallelism: 1,
            link: LinkSpec::gigabit_ethernet(),
            seed: 23,
            ..Default::default()
        };
        let mut engine = SimEngine::new(sim_layout(), cfg);
        let mut default_reports = Vec::new();
        for s in 0..3 {
            let r = engine.step(s);
            default_reports.push((r.wire_bytes_per_node, r.density.to_bits(), r.seconds.to_bits()));
        }
        assert_eq!(explicit, default_reports, "{method:?}");
        assert_eq!(er.to_bits(), engine.account.ratio().to_bits(), "{method:?}");
    }
}
