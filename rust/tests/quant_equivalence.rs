//! The `+q:<bits>` golden suite (DESIGN.md §17).
//!
//! The low-precision payload stage has four contracts, each pinned here
//! end to end through the engines rather than unit-by-unit:
//!
//! 1. **Alias**: `+q:2` *is* `+tern` — same parsed spec, same canonical
//!    name, same engine path, bit-identical runs.
//! 2. **Determinism**: every width is bit-identical across executor
//!    parallelism and across topologies' §4 contract, like every other
//!    pipeline.
//! 3. **Transport**: the real socket ring (`uds`) reproduces the
//!    simulator bit for bit at every width — the QBlob frame codec is
//!    invisible to the reports.
//! 4. **Pricing**: `CostModel::masked_q_{seconds,total_bytes}` equals
//!    the simulated wire time/bytes bit for bit for every width ×
//!    topology on a fresh clock, and the steady-state transport arena
//!    never grows.

use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use ringiwp::compress::quant::QuantWidth;
use ringiwp::compress::MethodSpec;
use ringiwp::exp::simrun::{SimCfg, SimEngine, WireEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{CostModel, LinkSpec, TopoKind, TransportKind};

const SIM_NODE_CAP: usize = 4; // SimEngine::SIM_NODE_CAP
const WATCHDOG: Duration = Duration::from_secs(180);

/// Every `+q` spec string, one per width (the 2-bit row spelled both
/// ways — the alias is part of the surface under test).
const Q_SPECS: [&str; 6] = [
    "iwp:fixed+q:16b",
    "iwp:fixed+q:16",
    "iwp:fixed+q:8",
    "iwp:fixed+q:4",
    "iwp:fixed+q:2",
    "iwp:fixed+tern",
];

fn with_watchdog<F>(label: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let worker = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            f();
            let _ = tx.send(());
        })
        .expect("spawn watchdog worker");
    match rx.recv_timeout(WATCHDOG) {
        Ok(()) | Err(RecvTimeoutError::Disconnected) => {
            if let Err(panic) = worker.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("{label}: still running after {WATCHDOG:?} — ring deadlock");
        }
    }
}

/// Conv + batchnorm + fc with an unaligned boundary and a one-element
/// bias — the same structurally-honest shape the transport oracle uses,
/// so every QBlob codec edge (partial pack byte, partial scale block)
/// is exercised.
fn layout() -> ParamLayout {
    ParamLayout::new(
        "quant_eq",
        vec![
            ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn".into(), vec![67], LayerKind::BatchNorm),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
            ("bias".into(), vec![1], LayerKind::Bias),
        ],
    )
}

fn cfg(spec: &str, nodes: usize, topology: TopoKind, transport: TransportKind) -> SimCfg {
    SimCfg {
        nodes,
        method: MethodSpec::parse(spec).expect("registry spec"),
        link: LinkSpec::new(1e9, 1e-5),
        topology,
        transport,
        wire_dir: None,
        seed: 42,
        ..Default::default()
    }
}

type Reports = Vec<(u64, u64, u64, u64)>;

fn engine_run(c: &SimCfg, steps: usize) -> (Reports, u64) {
    let mut engine = SimEngine::new(layout(), c.clone());
    let mut reports = Vec::new();
    for s in 0..steps {
        let r = engine.step(s);
        reports.push((
            r.wire_bytes_per_node,
            r.support_nnz,
            r.density.to_bits(),
            r.seconds.to_bits(),
        ));
    }
    (reports, engine.account.ratio().to_bits())
}

#[test]
fn q2_spec_is_the_tern_spec_end_to_end() {
    // The alias contract: `+q:2` parses to the very spec `+tern` does,
    // canonicalizes back to the `+tern` spelling, and runs bit-identical
    // through the engine on every topology — there is one 2-bit path,
    // not two.
    let a = MethodSpec::parse("iwp:fixed+q:2").unwrap();
    let b = MethodSpec::parse("iwp:fixed+tern").unwrap();
    assert_eq!(a, b);
    assert_eq!(a.name(), "iwp:fixed+tern");
    assert_eq!(a.quant, Some(QuantWidth::Q2));
    for topology in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
        let ra = engine_run(&cfg("iwp:fixed+q:2", 8, topology, TransportKind::Sim), 3);
        let rb = engine_run(&cfg("iwp:fixed+tern", 8, topology, TransportKind::Sim), 3);
        assert_eq!(ra, rb, "{}: alias ran a different path", topology.name());
    }
}

#[test]
fn every_width_is_bit_identical_across_parallelism() {
    // The §4 executor contract, per width: per-node encode closures are
    // disjoint and cross-node reduction happens in node order on the
    // coordinating thread, so worker count must never show in a report.
    for spec in Q_SPECS {
        for topology in [TopoKind::Flat, TopoKind::Tree] {
            let run = |w: usize| {
                let mut c = cfg(spec, 9, topology, TransportKind::Sim);
                c.parallelism = w;
                engine_run(&c, 3)
            };
            let seq = run(1);
            for w in [2usize, 4] {
                assert_eq!(
                    seq,
                    run(w),
                    "{spec} {} w={w}: §4 contract violated",
                    topology.name()
                );
            }
        }
    }
}

#[test]
fn uds_matches_sim_at_every_width() {
    // The transport oracle, restricted to the QBlob frame path: the
    // real socket ring must reproduce the simulator's reports bit for
    // bit at every width (the bench spec set only carries two widths;
    // this covers all of them, plus the alias spelling).
    with_watchdog("quant-uds", || {
        for spec in Q_SPECS {
            let mut sim = SimEngine::new(layout(), cfg(spec, 4, TopoKind::Flat, TransportKind::Sim));
            let mut wire = WireEngine::new(layout(), cfg(spec, 4, TopoKind::Flat, TransportKind::Uds))
                .unwrap_or_else(|e| panic!("{spec}: wire ring construction failed: {e}"));
            for s in 0..2 {
                let a = sim.step(s);
                let w = wire.step(s);
                assert_eq!(
                    (a.wire_bytes_per_node, a.support_nnz, a.density.to_bits()),
                    (
                        w.report.wire_bytes_per_node,
                        w.report.support_nnz,
                        w.report.density.to_bits()
                    ),
                    "{spec} step {s}: uds diverged from sim"
                );
                assert_eq!(
                    a.seconds.to_bits(),
                    w.report.seconds.to_bits(),
                    "{spec} step {s}: virtual clock diverged"
                );
                assert!(w.real_bytes > 0, "{spec} step {s}: no bytes crossed the ring");
            }
            assert_eq!(
                sim.account.ratio().to_bits(),
                wire.sim().account.ratio().to_bits(),
                "{spec}: compression ratio diverged"
            );
            wire.shutdown().unwrap_or_else(|e| panic!("{spec}: shutdown: {e}"));
        }
    });
}

#[test]
fn engine_wire_costs_equal_masked_q_closed_forms() {
    // CostModel::masked_q_{seconds,total_bytes} vs the simulated engine,
    // fresh clock, every width × topology. The Q2 row goes through the
    // tern engine path and must *still* land on masked_q — which in turn
    // equals masked_tern by construction.
    let lay = layout();
    let total = lay.total_params();
    let widths: [(&str, QuantWidth); 5] = [
        ("iwp:fixed+q:16b", QuantWidth::Bf16),
        ("iwp:fixed+q:16", QuantWidth::F16),
        ("iwp:fixed+q:8", QuantWidth::Q8),
        ("iwp:fixed+q:4", QuantWidth::Q4),
        ("iwp:fixed+q:2", QuantWidth::Q2),
    ];
    for topology in [TopoKind::Flat, TopoKind::Hier { group: 4 }, TopoKind::Tree] {
        for (spec, width) in widths {
            let c = cfg(spec, 8, topology, TransportKind::Sim);
            let model = CostModel::new(c.nodes, c.link);
            let k = c.mask_nodes.min(SIM_NODE_CAP);
            let mut engine = SimEngine::new(lay.clone(), c);
            let r = engine.step(0);
            let nnz = r.support_nnz as usize;
            assert!(nnz > 0, "{spec} {}: nothing selected", topology.name());
            assert_eq!(
                model.masked_q_seconds(topology, total, k, nnz, width).to_bits(),
                r.wire_seconds.to_bits(),
                "{spec} {}: wire time drifted from masked_q",
                topology.name()
            );
            assert_eq!(
                model.masked_q_total_bytes(topology, total, k, nnz, width),
                engine.net().total_bytes(),
                "{spec} {}: wire bytes drifted from masked_q",
                topology.name()
            );
        }
    }
}

#[test]
fn steady_state_arena_never_grows_at_any_width() {
    // The transport arena contract (DESIGN.md §9) holds for the QBlob
    // path too: after the first (warm-up) step, further steps never
    // reallocate arena buffers at any width.
    for spec in Q_SPECS {
        let mut engine = SimEngine::new(layout(), cfg(spec, 8, TopoKind::Flat, TransportKind::Sim));
        engine.step(0);
        let warm = engine.arena().grows();
        for s in 1..5 {
            engine.step(s);
            assert_eq!(
                engine.arena().grows(),
                warm,
                "{spec}: step {s} reallocated arena buffers"
            );
        }
    }
}
