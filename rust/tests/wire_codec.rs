//! Frame + codec roundtrip properties for the real wire transport
//! (`net::wire`, DESIGN.md §13).
//!
//! Two families, mirroring the module contract:
//!
//! * **roundtrip** — every payload kind encodes → decodes byte-exact at
//!   the edge shapes the engines actually produce (empty supports,
//!   unaligned trailing mask words, single-element layers, NaN/-0.0
//!   value bits);
//! * **totality** — malformed input (truncation at every cut, bad
//!   magic, version skew, unknown kinds, trailing bytes, shape-
//!   inconsistent payloads, random garbage) returns a typed
//!   [`WireError`], never a panic.

use std::sync::Arc;
use std::time::Duration;

use ringiwp::net::wire::codec;
use ringiwp::net::wire::frame::{HEADER_LEN, MAGIC};
use ringiwp::net::wire::peer::{EdgeRx, EdgeTx};
use ringiwp::net::wire::{
    FaultPlan, Frame, Kind, RecoveryCounters, RecoveryStats, TransportKind, WireError,
    WireStream, FLAG_CAP_V2, FLAG_TERN_BLOB, V1, VERSION,
};
use ringiwp::compress::quant::{QBlob, QuantWidth, QUANT_BLOCK};
use ringiwp::compress::terngrad::{TernBlob, TernGrad};
use ringiwp::net::LinkSpec;
use ringiwp::sparse::BitMask;
use ringiwp::util::rng::Rng;

/// A mask of length `len` with `every`-strided set bits (0 disables).
fn strided_mask(len: usize, every: usize) -> BitMask {
    let mut m = BitMask::zeros(len);
    if every > 0 {
        let mut i = 0;
        while i < len {
            m.set(i);
            i += every;
        }
    }
    m
}

fn assert_masks_equal(a: &BitMask, b: &BitMask) {
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert_eq!(a.get(i), b.get(i), "bit {i}");
    }
}

// ---------------------------------------------------------------- roundtrips

#[test]
fn dense_roundtrips_bit_exact_at_edge_shapes() {
    let nan = f32::from_bits(0x7fc0_0001);
    for values in [
        vec![],
        vec![1.5f32],
        vec![-0.0, 0.0, f32::MIN_POSITIVE, f32::MAX, nan],
        (0..257).map(|i| (i as f32).sin()).collect::<Vec<_>>(),
    ] {
        let decoded = codec::decode_dense(&codec::encode_dense(&values)).unwrap();
        assert_eq!(decoded.len(), values.len());
        for (a, b) in decoded.iter().zip(&values) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn support_roundtrips_at_edge_shapes() {
    // Empty support, single-element layer, word-aligned, and the
    // unaligned trailing-word shapes (65/67/127) where padding-bit
    // handling goes wrong first.
    for (len, every) in [
        (64, 0),
        (1, 1),
        (63, 1),
        (64, 3),
        (65, 64),
        (67, 7),
        (127, 2),
        (1000, 13),
    ] {
        let m = strided_mask(len, every);
        let decoded = codec::decode_support(&codec::encode_support(&m)).unwrap();
        assert_masks_equal(&m, &decoded);
        assert_eq!(decoded.count(), m.count());
    }
}

#[test]
fn masked_roundtrips_mask_and_compacted_values() {
    for (len, every) in [(70, 3), (64, 1), (9, 0), (1, 1)] {
        let m = strided_mask(len, every);
        let values: Vec<f32> = (0..m.count()).map(|i| i as f32 - 2.5).collect();
        let (dm, dv) = codec::decode_masked(&codec::encode_masked(&m, &values)).unwrap();
        assert_masks_equal(&m, &dm);
        assert_eq!(dv.len(), values.len());
        for (a, b) in dv.iter().zip(&values) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn terngrad_roundtrips_scales_and_codes() {
    for (len, n_scales) in [(1usize, 1usize), (4, 1), (5, 2), (1023, 7)] {
        let t = TernGrad {
            len,
            scales: (0..n_scales).map(|i| 0.25 * (i + 1) as f32).collect(),
            codes: (0..len.div_ceil(4)).map(|i| (i % 251) as u8).collect(),
        };
        let d = codec::decode_tern_grad(&codec::encode_tern_grad(&t)).unwrap();
        assert_eq!(d.len, t.len);
        assert_eq!(d.codes, t.codes);
        assert_eq!(d.scales.len(), t.scales.len());
        for (a, b) in d.scales.iter().zip(&t.scales) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn ternblob_roundtrips() {
    for len in [1usize, 4, 5, 77] {
        let t = TernBlob {
            len,
            scale: 0.125,
            codes: (0..len.div_ceil(4)).map(|i| i as u8).collect(),
        };
        let d = codec::decode_tern_blob(&codec::encode_tern_blob(&t)).unwrap();
        assert_eq!((d.len, d.scale.to_bits(), d.codes), (t.len, t.scale.to_bits(), t.codes));
    }
}

#[test]
fn qblob_roundtrips_every_width_at_edge_shapes() {
    // Empty payload, single element, one partial code byte, a partial
    // trailing scale block — built through the real encoder so the
    // shapes are exactly what the engines ship (DESIGN.md §17).
    let mut rng = Rng::new(17);
    for width in QuantWidth::ALL {
        for len in [0usize, 1, 5, QUANT_BLOCK + 3] {
            let mut vals = vec![0.0f32; len];
            rng.fill_normal(&mut vals, 0.0, 1.0);
            let q = QBlob::encode(&vals, width, &mut rng);
            let d = codec::decode_q_blob(&codec::encode_q_blob(&q)).unwrap();
            assert_eq!(d, q, "{width} len={len}");
        }
    }
}

#[test]
fn handshake_roundtrips() {
    assert_eq!(codec::decode_hello(&codec::encode_hello(3, 9)).unwrap(), (3, 9));
    let links = vec![LinkSpec::new(1e9, 1e-4), LinkSpec::new(5e8, 0.0)];
    let d = codec::decode_hello_ack(&codec::encode_hello_ack(&links)).unwrap();
    assert_eq!(d.len(), 2);
    assert_eq!(d[0].bandwidth_bps, 1e9);
    assert_eq!(d[1].latency_s, 0.0);
}

#[test]
fn frame_roundtrips_every_kind_over_buffer_and_stream() {
    for (kind, flags) in [
        (Kind::Dense, 0),
        (Kind::Sparse, 0),
        (Kind::Masked, 0),
        (Kind::Tern, 0),
        (Kind::Tern, FLAG_TERN_BLOB),
        (Kind::Quant, 0),
        (Kind::Hello, 0),
        (Kind::HelloAck, 0),
        (Kind::Shutdown, 0),
    ] {
        let f = Frame {
            kind,
            flags,
            origin: 5,
            ttl: 3,
            epoch: 11,
            payload: vec![0xAB; 7],
        };
        assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
        let mut cursor = std::io::Cursor::new(f.encode());
        assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
    }
}

// ----------------------------------------------------------------- totality

#[test]
fn version_bumped_frame_is_rejected_with_typed_error() {
    // The acceptance criterion verbatim: flip the version field of an
    // otherwise-valid frame and the decoder must answer with
    // WireError::Version, not a panic or a silent success.
    let mut bytes = Frame::new(Kind::Dense, 0, 1, 0, codec::encode_dense(&[1.0])).encode();
    let bumped = VERSION + 1;
    bytes[4..6].copy_from_slice(&bumped.to_le_bytes());
    match Frame::decode(&bytes) {
        Err(WireError::Version { got, want }) => {
            assert_eq!(got, bumped);
            assert_eq!(want, VERSION);
        }
        other => panic!("expected Version error, got {other:?}"),
    }
    // Same rejection off a stream, where a live peer would see it.
    let mut cursor = std::io::Cursor::new(bytes);
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Version { .. })
    ));
}

#[test]
fn bad_magic_and_bad_kind_are_typed() {
    let good = Frame::new(Kind::Sparse, 1, 2, 3, vec![0; 4]).encode();
    let mut bytes = good.clone();
    bytes[..4].copy_from_slice(b"NOPE");
    assert!(matches!(Frame::decode(&bytes), Err(WireError::BadMagic)));
    let mut bytes = good;
    bytes[6] = 0;
    assert!(matches!(Frame::decode(&bytes), Err(WireError::BadKind(0))));
}

#[test]
fn truncation_at_every_cut_is_typed_for_every_codec() {
    let m = strided_mask(67, 5);
    let values: Vec<f32> = (0..m.count()).map(|i| i as f32).collect();
    let tern = TernGrad {
        len: 9,
        scales: vec![1.0, 2.0],
        codes: vec![1, 2, 3],
    };
    let payloads: Vec<(&str, Vec<u8>)> = vec![
        ("dense", codec::encode_dense(&[1.0, 2.0, 3.0])),
        ("support", codec::encode_support(&m)),
        ("masked", codec::encode_masked(&m, &values)),
        ("tern_grad", codec::encode_tern_grad(&tern)),
        (
            "tern_blob",
            codec::encode_tern_blob(&TernBlob {
                len: 5,
                scale: 1.0,
                codes: vec![7, 8],
            }),
        ),
        (
            "q_blob",
            codec::encode_q_blob(&QBlob {
                width: QuantWidth::Q4,
                len: 5,
                block: QUANT_BLOCK,
                scales: vec![1.0],
                codes: vec![0x21, 0x43, 0x05],
            }),
        ),
        ("hello", codec::encode_hello(1, 4)),
        ("hello_ack", codec::encode_hello_ack(&[LinkSpec::new(1e9, 0.0); 2])),
    ];
    for (name, buf) in &payloads {
        let decode = |b: &[u8]| -> Result<(), WireError> {
            match *name {
                "dense" => codec::decode_dense(b).map(drop),
                "support" => codec::decode_support(b).map(drop),
                "masked" => codec::decode_masked(b).map(drop),
                "tern_grad" => codec::decode_tern_grad(b).map(drop),
                "tern_blob" => codec::decode_tern_blob(b).map(drop),
                "q_blob" => codec::decode_q_blob(b).map(drop),
                "hello" => codec::decode_hello(b).map(drop),
                "hello_ack" => codec::decode_hello_ack(b).map(drop),
                other => unreachable!("{other}"),
            }
        };
        // Every strict prefix fails typed; the full buffer succeeds.
        for cut in 0..buf.len() {
            assert!(
                decode(&buf[..cut]).is_err(),
                "{name}: truncation at {cut}/{} must fail",
                buf.len()
            );
        }
        decode(buf).unwrap_or_else(|e| panic!("{name}: full buffer must decode: {e}"));
        // Trailing garbage after a complete payload is rejected too —
        // a frame's payload_len and its codec must agree exactly.
        let mut long = buf.clone();
        long.push(0xEE);
        assert!(decode(&long).is_err(), "{name}: trailing byte must fail");
    }
}

#[test]
fn masked_payload_with_wrong_nnz_is_corrupt_not_panic() {
    let m = strided_mask(40, 4);
    let values: Vec<f32> = (0..m.count()).map(|i| i as f32).collect();
    let mut buf = codec::encode_masked(&m, &values);
    // nnz field (second u32) inflated past the mask's popcount.
    let bad = (m.count() + 1) as u32;
    buf[4..8].copy_from_slice(&bad.to_le_bytes());
    assert!(matches!(
        codec::decode_masked(&buf),
        Err(WireError::Truncated { .. }) | Err(WireError::Corrupt(_))
    ));
}

#[test]
fn hello_ack_with_nonpositive_bandwidth_is_corrupt() {
    let mut buf = codec::encode_hello_ack(&[LinkSpec::new(1e9, 0.0); 2]);
    // First link's bandwidth f64 → 0.0 (LinkSpec::new would assert;
    // the decoder must reject it as data instead).
    buf[4..12].copy_from_slice(&0.0f64.to_le_bytes());
    assert!(matches!(
        codec::decode_hello_ack(&buf),
        Err(WireError::Corrupt(_))
    ));
}

#[test]
fn random_garbage_never_panics_the_frame_decoder() {
    // Fuzz-lite with the deterministic SplitMix stream: whatever bytes
    // arrive, decoding returns — Ok for the rare valid frame, a typed
    // error otherwise, never a panic or an abort.
    let mut rng = Rng::new(0xC0DEC);
    for round in 0..2000 {
        let len = rng.below(64);
        let mut buf = vec![0u8; len];
        for b in buf.iter_mut() {
            *b = rng.below(256) as u8;
        }
        // Bias half the rounds toward "almost valid": correct magic and
        // version so the deeper header/payload paths get exercised.
        if round % 2 == 0 && buf.len() >= 6 {
            buf[..4].copy_from_slice(&MAGIC);
            buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
        }
        let _ = Frame::decode(&buf);
        let _ = Frame::decode_prefix(&buf);
        if buf.len() >= HEADER_LEN {
            let _ = codec::decode_dense(&buf[HEADER_LEN..]);
            let _ = codec::decode_support(&buf[HEADER_LEN..]);
            let _ = codec::decode_masked(&buf[HEADER_LEN..]);
            let _ = codec::decode_tern_grad(&buf[HEADER_LEN..]);
            let _ = codec::decode_tern_blob(&buf[HEADER_LEN..]);
            let _ = codec::decode_q_blob(&buf[HEADER_LEN..]);
            let _ = codec::decode_hello_ack(&buf[HEADER_LEN..]);
        }
    }
}

#[test]
fn stream_ending_mid_frame_is_typed_io_at_every_cut() {
    // A peer dying mid-frame (DESIGN.md §15): the reader sees the
    // stream end partway through a header or payload. Every cut —
    // empty stream, mid-header, exact header boundary, mid-payload —
    // must surface as the typed `WireError::Io` UnexpectedEof a
    // survivor can act on, never a panic or a partial frame.
    let full = Frame::new(Kind::Masked, 0, 2, 7, vec![0xCD; 33]).encode();
    for cut in [0, 1, HEADER_LEN / 2, HEADER_LEN, HEADER_LEN + 5, full.len() - 1] {
        let mut cursor = std::io::Cursor::new(full[..cut].to_vec());
        match Frame::read_from(&mut cursor) {
            Err(WireError::Io(e)) => assert_eq!(
                e.kind(),
                std::io::ErrorKind::UnexpectedEof,
                "cut at {cut}/{}",
                full.len()
            ),
            other => panic!("cut at {cut}: expected typed Io error, got {other:?}"),
        }
    }
    // And the uncut stream still parses — the cuts, not the frame,
    // were the problem.
    let mut cursor = std::io::Cursor::new(full);
    assert_eq!(Frame::read_from(&mut cursor).unwrap().payload.len(), 33);
}

// ------------------------------------------------- §16 integrity layer + ARQ

#[test]
fn every_single_bit_flip_on_a_v2_frame_is_detected() {
    // The CRC trailer covers header ‖ payload ‖ seq, so no single-bit
    // flip anywhere in a v2 transmission may decode silently — it must
    // surface as Checksum or an earlier typed header error.
    let f = Frame::new(Kind::Masked, 3, 2, 9, (0u8..32).collect());
    let clean = f.encode_at(VERSION, 7);
    assert_eq!(Frame::decode(&clean).unwrap(), f);
    for bit in 0..clean.len() * 8 {
        let mut bytes = clean.clone();
        bytes[bit / 8] ^= 1 << (bit % 8);
        assert!(
            Frame::decode(&bytes).is_err(),
            "bit flip at {bit} (byte {}) must be detected, not silently decoded",
            bit / 8
        );
    }
}

#[test]
fn duplicated_and_stale_frames_are_suppressed_by_sequence() {
    // A stop-and-wait receiver must deliver each sequence number once,
    // in order, no matter how often the bytes show up: dup faults and
    // stale retransmits (the "reordered frame" a byte stream can
    // actually produce) are dropped silently, never re-ACKed.
    let (mut w, r) = WireStream::pair(TransportKind::Uds).unwrap();
    let counters = Arc::new(RecoveryCounters::new());
    let mut rx = EdgeRx::new(r, 1, VERSION, Duration::from_millis(500), counters.clone()).unwrap();
    let frame = |i: u32| Frame::new(Kind::Dense, 0, 1, i, codec::encode_dense(&[i as f32]));
    let writer = std::thread::spawn(move || {
        // seq 1, dup of 1, seq 2, stale 1 again, seq 3 — the writer
        // never reads the ACKs; the socket buffer absorbs them.
        for seq in [1u32, 1, 2, 1, 3] {
            frame(seq).write_to_at(&mut w, VERSION, seq).unwrap();
            w.flush().unwrap();
        }
        w
    });
    let mut got = Vec::new();
    while got.len() < 3 {
        if let Some(f) = rx.recv().unwrap() {
            got.push(f.epoch);
        }
    }
    let _w = writer.join().unwrap();
    assert_eq!(got, vec![1, 2, 3], "in-order delivery, each seq exactly once");
    let s = counters.snapshot();
    assert_eq!(s.dup_drops, 2, "{s}");
    assert_eq!((s.retransmits, s.nacks), (0, 0), "{s}");
}

#[test]
fn flip_fault_recovers_via_nack_and_retransmit() {
    // A scheduled bit flip on the first attempt: the receiver NACKs,
    // the sender retransmits, and the delivered frame is bit-identical
    // — with the counters proving the fault actually fired.
    let plan = FaultPlan::parse("seed=5,flip@0:0").unwrap();
    let counters = Arc::new(RecoveryCounters::new());
    let (a, b) = WireStream::pair(TransportKind::Uds).unwrap();
    let mut tx = EdgeTx::new(
        a,
        VERSION,
        plan.edge_faults(0, 1),
        4,
        Duration::from_millis(2_000),
        counters.clone(),
    )
    .unwrap();
    let mut rx = EdgeRx::new(b, 1, VERSION, Duration::from_millis(150), counters.clone()).unwrap();
    let f = Frame::new(Kind::Dense, 0, 1, 3, codec::encode_dense(&[1.0, -2.5]));
    let sent = f.clone();
    let sender = std::thread::spawn(move || {
        tx.send(&sent).unwrap();
        tx
    });
    let got = loop {
        if let Some(g) = rx.recv().unwrap() {
            break g;
        }
    };
    let _tx = sender.join().unwrap();
    assert_eq!(got, f, "recovered frame must be bit-identical");
    let s = counters.snapshot();
    assert!(s.retransmits >= 1, "{s}");
    assert!(s.nacks >= 1, "{s}");
    assert_eq!(s.dup_drops, 0, "{s}");
}

#[test]
fn hello_negotiation_rides_v1_flags_and_v1_sessions_skip_the_arq() {
    // Hello always travels at wire version 1 with the v2 capability in
    // the flags byte — that is what makes negotiation with old peers
    // possible at all (the body layout never changes).
    let mut hello = Frame::new(Kind::Hello, 2, 0, 0, codec::encode_hello(2, 4));
    hello.flags = FLAG_CAP_V2;
    let bytes = hello.encode();
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), V1);
    let (f, meta, used) = Frame::decode_prefix_ext(&bytes).unwrap();
    assert_eq!(used, bytes.len());
    assert_eq!(meta.version, V1);
    assert_eq!(f.flags & FLAG_CAP_V2, FLAG_CAP_V2);
    assert_eq!(codec::decode_hello(&f.payload).unwrap(), (2, 4));
    // An ack without the flag pins the session to v1: edges write
    // plain trailerless frames and the sender never waits for an ACK.
    let counters = Arc::new(RecoveryCounters::new());
    let (a, b) = WireStream::pair(TransportKind::Uds).unwrap();
    let mut tx =
        EdgeTx::new(a, V1, None, 4, Duration::from_millis(500), counters.clone()).unwrap();
    let mut rx = EdgeRx::new(b, 1, V1, Duration::from_millis(500), counters.clone()).unwrap();
    let f = Frame::new(Kind::Dense, 0, 1, 0, codec::encode_dense(&[4.5]));
    tx.send(&f).unwrap(); // returns immediately — no ACK round-trip
    assert_eq!(rx.recv().unwrap(), Some(f));
    assert_eq!(counters.snapshot(), RecoveryStats::default());
}

#[test]
fn oversized_payload_len_is_rejected_before_allocation() {
    let mut bytes = Frame::new(Kind::Dense, 0, 0, 0, Vec::new()).encode();
    bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
    // Buffer decode: the cap fires (Corrupt), not a 4 GiB allocation.
    assert!(matches!(Frame::decode(&bytes), Err(WireError::Corrupt(_))));
    let mut cursor = std::io::Cursor::new(bytes);
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Corrupt(_))
    ));
}
