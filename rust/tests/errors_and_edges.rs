//! Failure injection + edge cases: the system must fail loudly and
//! precisely, never corrupt state silently.

use ringiwp::compress::Method;
use ringiwp::config::Config;
use ringiwp::model::{zoo, LayerKind, ParamLayout};
use ringiwp::runtime::Runtime;
use ringiwp::sparse::BitMask;
use ringiwp::util::cli::Args;
use ringiwp::util::json;

#[test]
fn runtime_missing_artifacts_dir_is_actionable() {
    let err = Runtime::cpu("/nonexistent/path/xyz").err().expect("must fail");
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn runtime_rejects_missing_artifact() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(rt) = Runtime::cpu(&dir) else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    assert!(rt.load("no_such_artifact").is_err());
}

#[test]
fn runtime_rejects_wrong_input_arity_and_shape() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(rt) = Runtime::cpu(&dir) else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let art = rt.load("importance_m8192").unwrap();
    // Wrong arity.
    assert!(art.run_f32(&[&[0.0f32; 8192]]).is_err());
    // Wrong shape.
    let bad = vec![0.0f32; 100];
    let good = vec![0.0f32; 8192];
    let one = [0.5f32];
    let err = art
        .run_f32(&[&bad, &good, &good, &one, &one])
        .unwrap_err()
        .to_string();
    assert!(err.contains("elements given"), "{err}");
}

#[test]
fn corrupted_manifest_fails_cleanly() {
    let dir = std::env::temp_dir().join("ringiwp_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("broken.manifest.json"), "{ not json !").unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule nonsense").unwrap();
    std::fs::write(dir.join("index.json"), r#"{"artifacts": ["broken"]}"#).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let err = rt.load("broken").err().expect("must fail").to_string();
    assert!(err.contains("manifest"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_validation_is_comprehensive() {
    let bad_cases: Vec<Box<dyn Fn(&mut Config)>> = vec![
        Box::new(|c| c.nodes = 0),
        Box::new(|c| c.nodes = 1),
        Box::new(|c| c.momentum = 1.0),
        Box::new(|c| c.momentum = -0.1),
        Box::new(|c| c.lr = 0.0),
        Box::new(|c| c.threshold = -1.0),
        Box::new(|c| c.mask_nodes = 0),
        Box::new(|c| c.dgc_density = 1.5),
        Box::new(|c| c.steps_per_epoch = 0),
    ];
    for (i, mutate) in bad_cases.iter().enumerate() {
        let mut c = Config::default();
        mutate(&mut c);
        assert!(c.validate().is_err(), "bad case {i} passed validation");
    }
}

#[test]
fn cli_flags_flow_into_config() {
    let a = Args::parse(
        ["train", "--method", "dgc", "--dgc-density", "0.05", "--seed", "9"]
            .into_iter()
            .map(String::from),
    );
    let c = Config::default().apply_args(&a).unwrap();
    assert_eq!(c.method, Method::Dgc.spec());
    assert!((c.dgc_density - 0.05).abs() < 1e-12);
    assert_eq!(c.seed, 9);
}

#[test]
fn method_spec_grammar_flows_and_rejects_through_every_entry_point() {
    use ringiwp::compress::MethodSpec;
    // New-grammar specs through the CLI flag…
    let a = Args::parse(
        ["train", "--method", "iwp:vargate:2:8+nosel+tern"]
            .into_iter()
            .map(String::from),
    );
    let c = Config::default().apply_args(&a).unwrap();
    assert_eq!(c.method, MethodSpec::parse("iwp:vargate:2:8+nosel+tern").unwrap());
    assert_eq!(c.method.name(), "iwp:vargate:2:8+nosel+tern");
    // …and the config file key (one shared entry point: MethodSpec::parse).
    let path = std::env::temp_dir().join("ringiwp_methodspec_test.conf");
    std::fs::write(&path, "method = dgc:layerwise+warmup:3\n").unwrap();
    let a = Args::parse(
        ["train", "--config", path.to_str().unwrap()]
            .into_iter()
            .map(String::from),
    );
    let c = Config::default().apply_args(&a).unwrap();
    assert_eq!(c.method.name(), "dgc:layerwise+warmup:3");
    // Rejects are uniform across entry points too.
    std::fs::write(&path, "method = dense+tern\n").unwrap();
    let a = Args::parse(
        ["train", "--config", path.to_str().unwrap()]
            .into_iter()
            .map(String::from),
    );
    assert!(Config::default().apply_args(&a).is_err());
    let _ = std::fs::remove_file(path);
    for bad in ["iwp:vargate:", "dgc:topk+sel", "terngrad+warmup:1"] {
        let a = Args::parse(
            ["train", "--method", bad].into_iter().map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err(), "`{bad}`");
    }
}

#[test]
fn config_file_roundtrip() {
    let path = std::env::temp_dir().join("ringiwp_test.conf");
    std::fs::write(&path, "nodes = 12\nmethod = terngrad\nlr = 0.2\n").unwrap();
    let a = Args::parse(
        ["train", "--config", path.to_str().unwrap()]
            .into_iter()
            .map(String::from),
    );
    let c = Config::default().apply_args(&a).unwrap();
    assert_eq!(c.nodes, 12);
    assert_eq!(c.method, Method::TernGrad.spec());
    assert!((c.lr - 0.2).abs() < 1e-7);
    let _ = std::fs::remove_file(path);
}

#[test]
fn json_error_reports_position() {
    let err = json::parse("{\"a\": }").unwrap_err();
    assert!(err.pos > 0);
    assert!(format!("{err}").contains("byte"));
}

#[test]
fn bitmask_length_mismatch_panics() {
    let a = BitMask::zeros(10);
    let mut b = BitMask::zeros(20);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        b.or_assign(&a);
    }));
    assert!(result.is_err());
}

#[test]
fn zoo_lookup_errors() {
    assert!(zoo::by_name("vgg16").is_err());
}

#[test]
fn layout_split_rejects_wrong_len() {
    let l = ParamLayout::new("t", vec![("a".into(), vec![4], LayerKind::Fc)]);
    let result = std::panic::catch_unwind(|| {
        let flat = vec![0.0f32; 5];
        let _ = l.split(&flat);
    });
    assert!(result.is_err());
}

#[test]
fn trainer_rejects_unknown_model() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(rt) = Runtime::cpu(&dir) else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let cfg = Config {
        model: "resnet9000".into(),
        ..Config::default()
    };
    assert!(ringiwp::coordinator::Trainer::new(cfg, &rt).is_err());
}
