//! The fused one-pass kernel contract (DESIGN.md §11), pinned at the
//! engine level: `SimEngine`'s IWP step — which runs
//! `fuse::score_select_compact` + `ResidualStore::clear_masked` — must
//! reproduce the retained multi-pass reference chain
//! (`accumulate` → `fill_u` → `score_and_mask` → per-layer mask merge →
//! `take_masked`) **bit for bit**: step reports, trailing layer stats,
//! and residual states. The kernel-level pins (every selection mode,
//! warm/cold stores, RNG lockstep) live in `compress::fuse`'s unit
//! tests; this file replays the whole engine chain against a from-
//! scratch multi-pass reimplementation for both IWP methods × both
//! threshold policies × both selection modes.

use ringiwp::compress::importance::{score_and_mask, LayerStats, EPS};
use ringiwp::compress::residual::ResidualStore;
use ringiwp::compress::select;
use ringiwp::compress::threshold::{ThresholdCfg, ThresholdPolicy};
use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::grad::SynthGrads;
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, RingNet, TopoKind};
use ringiwp::ring::{masked, Arena};
use ringiwp::sparse::BitMask;
use ringiwp::util::rng::Rng;

fn layout() -> ParamLayout {
    ParamLayout::new(
        "fused_eq",
        vec![
            ("conv1".into(), vec![16, 8, 3, 3], LayerKind::Conv),
            ("bn1".into(), vec![32], LayerKind::BatchNorm),
            ("fc".into(), vec![200, 10], LayerKind::Fc),
        ],
    )
}

/// The engine's IWP step, re-derived from the retained multi-pass
/// primitives (the exact pre-fusion chain, flat topology, sequential).
/// Returns per-step `(wire_bytes_per_node, density bits, seconds bits)`
/// plus the final trailing stats.
fn multipass_reference(
    cfg: &SimCfg,
    layout: &ParamLayout,
    steps: usize,
) -> (Vec<(u64, u64, u64)>, Vec<LayerStats>) {
    let total = layout.total_params();
    let nodes = cfg.nodes;
    let sim_nodes = nodes.min(4); // SimEngine::SIM_NODE_CAP
    let synth = SynthGrads::new(layout.clone(), cfg.seed ^ 0x5EED);
    let mut root = Rng::new(cfg.seed);
    let mut rngs: Vec<Rng> = (0..nodes).map(|i| root.split(i as u64)).collect();
    let mut ctl_rng = root.split(0xC011);
    let mut stores: Vec<ResidualStore> = (0..sim_nodes)
        .map(|_| ResidualStore::new(total, cfg.momentum))
        .collect();
    let policy = if cfg.method == Method::IwpLayerwise.spec() {
        ThresholdPolicy::Layerwise(ThresholdCfg {
            alpha: cfg.threshold,
            beta: cfg.beta,
            c: cfg.c,
            ..Default::default()
        })
    } else {
        ThresholdPolicy::Fixed(cfg.threshold)
    };
    let mut net = RingNet::new(nodes, cfg.link, 0.05);
    let mut arena = Arena::for_nodes(nodes);
    let mut prev_stats = vec![LayerStats::default(); layout.n_layers()];
    let mut grads = vec![vec![0.0f32; total]; sim_nodes];
    let mut reports = Vec::new();

    for step in 0..steps {
        let epoch = step / cfg.steps_per_epoch.max(1);
        for (node, grad) in grads.iter_mut().enumerate() {
            synth.gen_step_node(step, node, grad);
            for v in grad.iter_mut() {
                *v *= 0.85 + 0.3 * rngs[node].uniform();
            }
        }
        let t0 = net.clock();
        for (node, store) in stores.iter_mut().enumerate() {
            store.accumulate(&grads[node]);
        }
        let thrs = policy.layer_thresholds(layout, &prev_stats, epoch, 1.0);
        let broadcasters = ctl_rng.choose_distinct(sim_nodes, cfg.mask_nodes.min(sim_nodes));
        let mut masks = Vec::new();
        let mut new_stats = vec![LayerStats::default(); layout.n_layers()];
        let mut u = vec![1.0f32; total];
        let mut imp = vec![0.0f32; total];
        for &b in &broadcasters {
            let pending: Vec<f32> = stores[b].pending().to_vec();
            let mut mask = BitMask::zeros(total);
            for (li, layer) in layout.layers().iter().enumerate() {
                let r = layer.range();
                select::fill_u(&mut rngs[b], cfg.random_select, &mut u[..layer.size]);
                let mut layer_mask = BitMask::zeros(layer.size);
                let st = score_and_mask(
                    &pending[r.clone()],
                    &synth.weights[r.clone()],
                    &u[..layer.size],
                    thrs[li],
                    EPS,
                    &mut imp[..layer.size],
                    &mut layer_mask,
                );
                for i in layer_mask.iter_set() {
                    mask.set(r.start + i);
                }
                new_stats[li].merge(&st);
            }
            masks.push(mask);
        }
        prev_stats = new_stats;
        let mask_refs: Vec<&BitMask> = masks.iter().collect();
        let (shared, rep) = masked::allreduce_bytes_only_in(&mut net, &mask_refs, &mut arena);
        for store in stores.iter_mut() {
            let _ = store.take_masked(&shared);
        }
        net.advance(0.35);
        reports.push((
            rep.mean_bytes_per_node() as u64,
            shared.density().to_bits(),
            (net.clock() - t0).to_bits(),
        ));
    }
    (reports, prev_stats)
}

fn engine_run(
    cfg: &SimCfg,
    layout: &ParamLayout,
    steps: usize,
) -> (Vec<(u64, u64, u64)>, Vec<LayerStats>) {
    let mut engine = SimEngine::new(layout.clone(), cfg.clone());
    let mut reports = Vec::new();
    for s in 0..steps {
        let r = engine.step(s);
        reports.push((r.wire_bytes_per_node, r.density.to_bits(), r.seconds.to_bits()));
    }
    (reports, engine.prev_stats().to_vec())
}

fn stat_bits(s: &LayerStats) -> (u64, u64, u64, u64) {
    (
        s.sum.to_bits(),
        s.sumsq.to_bits(),
        s.n_selected.to_bits(),
        s.n.to_bits(),
    )
}

#[test]
fn fused_engine_step_matches_multipass_reference_bitwise() {
    let layout = layout();
    for method in [Method::IwpFixed, Method::IwpLayerwise] {
        for random_select in [true, false] {
            let cfg = SimCfg {
                nodes: 4,
                method: method.spec(),
                threshold: 0.04,
                random_select,
                seed: 91,
                link: LinkSpec::gigabit_ethernet(),
                parallelism: 1,
                topology: TopoKind::Flat,
                ..Default::default()
            };
            let (ref_reports, ref_stats) = multipass_reference(&cfg, &layout, 4);
            let (eng_reports, eng_stats) = engine_run(&cfg, &layout, 4);
            assert_eq!(
                ref_reports, eng_reports,
                "{method:?} random_select={random_select}: step reports diverged"
            );
            assert_eq!(ref_stats.len(), eng_stats.len());
            for (li, (a, b)) in ref_stats.iter().zip(&eng_stats).enumerate() {
                assert_eq!(
                    stat_bits(a),
                    stat_bits(b),
                    "{method:?} random_select={random_select}: layer {li} stats diverged"
                );
            }
        }
    }
}

#[test]
fn fused_engine_is_bit_identical_across_parallelism() {
    // The §4 contract survives the fusion: fused scoring fans out per
    // broadcaster node with cloned-out RNG streams, so any executor
    // width replays the sequential reports exactly.
    let layout = layout();
    for method in [Method::IwpFixed, Method::IwpLayerwise] {
        let cfg = |w: usize| SimCfg {
            nodes: 4,
            method: method.spec(),
            threshold: 0.04,
            seed: 23,
            link: LinkSpec::gigabit_ethernet(),
            parallelism: w,
            topology: TopoKind::Flat,
            ..Default::default()
        };
        let (seq, seq_stats) = engine_run(&cfg(1), &layout, 3);
        for w in [2usize, 4] {
            let (par, par_stats) = engine_run(&cfg(w), &layout, 3);
            assert_eq!(seq, par, "{method:?} w={w}");
            for (a, b) in seq_stats.iter().zip(&par_stats) {
                assert_eq!(stat_bits(a), stat_bits(b), "{method:?} w={w}");
            }
        }
    }
}
