//! Cross-module property tests: the invariants that make the paper's
//! accounting trustworthy, exercised end-to-end across compress + ring +
//! net (no PJRT needed).

use ringiwp::compress::importance::{score_and_mask, EPS};
use ringiwp::compress::residual::ResidualStore;
use ringiwp::compress::terngrad::TernGrad;
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, RingNet};
use ringiwp::ring;
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::prop::forall;
use ringiwp::util::rng::Rng;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
}

#[test]
fn residual_plus_masked_ring_conserves_gradient_mass() {
    // What every node applies + what stays pending == what was injected,
    // across multiple steps of IWP with arbitrary masks. Momentum 0 so
    // conservation is exact.
    forall("IWP pipeline conserves mass", 25, |g| {
        let n = g.usize_in(2, 5);
        let len = g.usize_in(8, 120);
        let steps = g.usize_in(1, 4);
        let mut stores: Vec<ResidualStore> =
            (0..n).map(|_| ResidualStore::new(len, 0.0)).collect();
        let mut injected = vec![0.0f64; len];
        let mut applied = vec![0.0f64; len];
        for _ in 0..steps {
            for store in stores.iter_mut() {
                let grad = g.vec_normal(len, 0.0, 1.0);
                for (acc, &v) in injected.iter_mut().zip(&grad) {
                    *acc += v as f64;
                }
                store.accumulate(&grad);
            }
            // Random broadcaster mask.
            let mut mask = BitMask::zeros(len);
            for i in 0..len {
                if g.bool() {
                    mask.set(i);
                }
            }
            let values: Vec<&[f32]> = stores.iter().map(|s| s.pending()).collect();
            let mut nw = net(n);
            let (shared, summed, _) = ring::masked::allreduce(&mut nw, &[&mask], &values);
            for (k, i) in shared.iter_set().enumerate() {
                applied[i] += summed[k] as f64;
            }
            for store in stores.iter_mut() {
                let _ = store.take_masked(&shared);
            }
        }
        for i in 0..len {
            let pending: f64 = stores.iter().map(|s| s.pending()[i] as f64).sum();
            assert!(
                (injected[i] - applied[i] - pending).abs() < 1e-3,
                "coord {i}: injected {} != applied {} + pending {}",
                injected[i],
                applied[i],
                pending
            );
        }
    });
}

#[test]
fn dense_ring_byte_formula_exact() {
    forall("dense ring bytes == 2(N-1)/N * V", 30, |g| {
        let n = g.usize_in(2, 10);
        let len = g.usize_in(n, 500);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, -1.0, 1.0)).collect();
        let mut nw = net(n);
        let rep = ring::dense::allreduce(&mut nw, &mut bufs);
        // With (possibly uneven) chunking each node sends every chunk
        // except its own twice-ish; totals must be exactly 2(N-1)*V*4
        // across the ring.
        assert_eq!(rep.total_bytes(), 2 * (n as u64 - 1) * (len as u64 * 4));
    });
}

#[test]
fn masked_bytes_scale_with_density_not_len() {
    forall("masked wire ~ support", 20, |g| {
        let len = 50_000;
        let n = 4;
        let nnz = g.usize_in(1, 400);
        let mut mask = BitMask::zeros(len);
        for _ in 0..nnz {
            mask.set(g.usize_in(0, len));
        }
        let mut nw = net(n);
        let (shared, rep) = ring::masked::allreduce_bytes_only(&mut nw, &[&mask]);
        // Mask allgather cost is fixed; value cost ~ 4 bytes/selected * 2.
        let fixed = (len as u64).div_ceil(8) * (n as u64 - 1);
        let value_budget = 2 * 4 * shared.count() as u64 + 64 * n as u64;
        assert!(
            rep.mean_bytes_per_node() <= (fixed + value_budget) as f64,
            "bytes {} vs budget {}",
            rep.mean_bytes_per_node(),
            fixed + value_budget
        );
    });
}

#[test]
fn terngrad_roundtrip_magnitudes_bounded_by_scale() {
    forall("terngrad |decode| <= layer max|g|", 30, |g| {
        let len = g.usize_in(4, 300);
        let layout = ParamLayout::new(
            "t",
            vec![("l".into(), vec![len], LayerKind::Fc)],
        );
        let grad = g.vec_normal(len, 0.0, 0.3);
        let max = grad.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut rng = Rng::new(g.case as u64);
        let t = TernGrad::encode(&grad, &layout, &mut rng);
        for v in t.decode(&layout) {
            assert!(v.abs() <= max + 1e-6);
        }
        // 2-bit wire size.
        assert!(t.wire_bytes() <= (len as u64).div_ceil(4) + 16);
    });
}

#[test]
fn sparse_wire_never_exceeds_dense() {
    forall("cheapest codec <= dense", 50, |g| {
        let len = g.usize_in(1, 5000);
        let density = g.choice(&[0.001, 0.01, 0.3, 0.9]);
        let dense_vec = g.vec_sparse(len, density);
        let sv = SparseVec::from_dense(&dense_vec);
        let dense_bytes =
            ringiwp::sparse::wire_bytes(ringiwp::sparse::WireFormat::Dense, len, sv.nnz());
        assert!(sv.wire_bytes() <= dense_bytes);
    });
}

#[test]
fn score_and_mask_density_monotone_in_threshold() {
    forall("higher thr -> fewer selected", 25, |g| {
        let len = g.usize_in(32, 1000);
        let grad = g.vec_normal(len, 0.0, 0.01);
        let w = g.vec_normal(len, 0.0, 0.5);
        let u = vec![1.0f32; len];
        let mut imp = vec![0.0f32; len];
        let mut prev = usize::MAX;
        for thr in [0.001f32, 0.01, 0.1, 1.0] {
            let mut mask = BitMask::zeros(len);
            score_and_mask(&grad, &w, &u, thr, EPS, &mut imp, &mut mask);
            assert!(mask.count() <= prev);
            prev = mask.count();
        }
    });
}

#[test]
fn ring_time_dominated_by_slowest_round() {
    // Latency model: K rounds with per-round max semantics.
    forall("round time == max link", 30, |g| {
        let n = g.usize_in(2, 8);
        let spec = LinkSpec::new(1e6, 0.001);
        let mut nw = RingNet::new(n, spec, 1.0);
        let bytes: Vec<u64> = (0..n).map(|_| g.usize_in(0, 100_000) as u64).collect();
        let dur = nw.round(&bytes);
        let expect = bytes
            .iter()
            .map(|&b| spec.transfer_time(b))
            .fold(0.0f64, f64::max);
        assert!((dur - expect).abs() < 1e-12);
    });
}
