//! Cross-module property tests: the invariants that make the paper's
//! accounting trustworthy, exercised end-to-end across compress + ring +
//! net (no PJRT needed).

use ringiwp::compress::importance::{score_and_mask, EPS};
use ringiwp::compress::pipeline;
use ringiwp::compress::quant::{QBlob, QuantWidth};
use ringiwp::compress::residual::ResidualStore;
use ringiwp::compress::terngrad::TernGrad;
use ringiwp::compress::{Compressor, MethodSpec, StageCfg};
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::net::{LinkSpec, RecoveryMode, RingNet};
use ringiwp::ring;
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::prop::forall;
use ringiwp::util::rng::Rng;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
}

#[test]
fn residual_plus_masked_ring_conserves_gradient_mass() {
    // What every node applies + what stays pending == what was injected,
    // across multiple steps of IWP with arbitrary masks. Momentum 0 so
    // conservation is exact.
    forall("IWP pipeline conserves mass", 25, |g| {
        let n = g.usize_in(2, 5);
        let len = g.usize_in(8, 120);
        let steps = g.usize_in(1, 4);
        let mut stores: Vec<ResidualStore> =
            (0..n).map(|_| ResidualStore::new(len, 0.0)).collect();
        let mut injected = vec![0.0f64; len];
        let mut applied = vec![0.0f64; len];
        for _ in 0..steps {
            for store in stores.iter_mut() {
                let grad = g.vec_normal(len, 0.0, 1.0);
                for (acc, &v) in injected.iter_mut().zip(&grad) {
                    *acc += v as f64;
                }
                store.accumulate(&grad);
            }
            // Random broadcaster mask.
            let mut mask = BitMask::zeros(len);
            for i in 0..len {
                if g.bool() {
                    mask.set(i);
                }
            }
            let values: Vec<&[f32]> = stores.iter().map(|s| s.pending()).collect();
            let mut nw = net(n);
            let (shared, summed, _) = ring::masked::allreduce(&mut nw, &[&mask], &values);
            for (k, i) in shared.iter_set().enumerate() {
                applied[i] += summed[k] as f64;
            }
            for store in stores.iter_mut() {
                let _ = store.take_masked(&shared);
            }
        }
        for i in 0..len {
            let pending: f64 = stores.iter().map(|s| s.pending()[i] as f64).sum();
            assert!(
                (injected[i] - applied[i] - pending).abs() < 1e-3,
                "coord {i}: injected {} != applied {} + pending {}",
                injected[i],
                applied[i],
                pending
            );
        }
    });
}

#[test]
fn dense_ring_byte_formula_exact() {
    forall("dense ring bytes == 2(N-1)/N * V", 30, |g| {
        let n = g.usize_in(2, 10);
        let len = g.usize_in(n, 500);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, -1.0, 1.0)).collect();
        let mut nw = net(n);
        let rep = ring::dense::allreduce(&mut nw, &mut bufs);
        // With (possibly uneven) chunking each node sends every chunk
        // except its own twice-ish; totals must be exactly 2(N-1)*V*4
        // across the ring.
        assert_eq!(rep.total_bytes(), 2 * (n as u64 - 1) * (len as u64 * 4));
    });
}

#[test]
fn masked_bytes_scale_with_density_not_len() {
    forall("masked wire ~ support", 20, |g| {
        let len = 50_000;
        let n = 4;
        let nnz = g.usize_in(1, 400);
        let mut mask = BitMask::zeros(len);
        for _ in 0..nnz {
            mask.set(g.usize_in(0, len));
        }
        let mut nw = net(n);
        let (shared, rep) = ring::masked::allreduce_bytes_only(&mut nw, &[&mask]);
        // Mask allgather cost is fixed; value cost ~ 4 bytes/selected * 2.
        let fixed = (len as u64).div_ceil(8) * (n as u64 - 1);
        let value_budget = 2 * 4 * shared.count() as u64 + 64 * n as u64;
        assert!(
            rep.mean_bytes_per_node() <= (fixed + value_budget) as f64,
            "bytes {} vs budget {}",
            rep.mean_bytes_per_node(),
            fixed + value_budget
        );
    });
}

#[test]
fn terngrad_roundtrip_magnitudes_bounded_by_scale() {
    forall("terngrad |decode| <= layer max|g|", 30, |g| {
        let len = g.usize_in(4, 300);
        let layout = ParamLayout::new(
            "t",
            vec![("l".into(), vec![len], LayerKind::Fc)],
        );
        let grad = g.vec_normal(len, 0.0, 0.3);
        let max = grad.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut rng = Rng::new(g.case as u64);
        let t = TernGrad::encode(&grad, &layout, &mut rng);
        for v in t.decode(&layout) {
            assert!(v.abs() <= max + 1e-6);
        }
        // 2-bit wire size.
        assert!(t.wire_bytes() <= (len as u64).div_ceil(4) + 16);
    });
}

#[test]
fn qblob_stochastic_rounding_is_unbiased_at_every_width() {
    // The `+q:<bits>` contract (DESIGN.md §17): for every k-bit width,
    // E[decode(encode(v))] == v — averaging many independent encodes
    // converges on the payload, coordinate-wise, within 5σ of the
    // rounding noise (σ ≤ unit/(2√trials) per coordinate, unit = the
    // block's quantization step). The float widths have no randomness
    // at all: two encodes under diverging RNG streams are identical.
    forall("E[qblob decode] == payload", 4, |g| {
        let len = g.usize_in(16, 96);
        let vals = g.vec_normal(len, 0.0, 0.5);
        let scale = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut rng = Rng::new(1000 + g.case as u64);
        for width in QuantWidth::ALL {
            if width.is_float() {
                let mut r1 = Rng::new(1);
                let mut r2 = Rng::new(2);
                r2.uniform(); // desynchronize the streams
                assert_eq!(
                    QBlob::encode(&vals, width, &mut r1),
                    QBlob::encode(&vals, width, &mut r2),
                    "{width}: float widths must not consume randomness"
                );
                continue;
            }
            let trials = 3000usize;
            let mut acc = vec![0.0f64; len];
            let mut dec = vec![0.0f32; len];
            for _ in 0..trials {
                let blob = QBlob::encode(&vals, width, &mut rng);
                dec.fill(0.0);
                blob.add_decoded_into(&mut dec);
                for (a, &d) in acc.iter_mut().zip(&dec) {
                    *a += d as f64;
                }
            }
            let unit = scale as f64 / width.levels() as f64;
            let tol = 5.0 * unit / 2.0 / (trials as f64).sqrt();
            for (i, (&v, &a)) in vals.iter().zip(&acc).enumerate() {
                let mean = a / trials as f64;
                assert!(
                    (mean - v as f64).abs() <= tol,
                    "{width} coord {i}: mean {mean} vs {v} (tol {tol})"
                );
            }
        }
    });
}

#[test]
fn sparse_wire_never_exceeds_dense() {
    forall("cheapest codec <= dense", 50, |g| {
        let len = g.usize_in(1, 5000);
        let density = g.choice(&[0.001, 0.01, 0.3, 0.9]);
        let dense_vec = g.vec_sparse(len, density);
        let sv = SparseVec::from_dense(&dense_vec);
        let dense_bytes =
            ringiwp::sparse::wire_bytes(ringiwp::sparse::WireFormat::Dense, len, sv.nnz());
        assert!(sv.wire_bytes() <= dense_bytes);
    });
}

#[test]
fn score_and_mask_density_monotone_in_threshold() {
    forall("higher thr -> fewer selected", 25, |g| {
        let len = g.usize_in(32, 1000);
        let grad = g.vec_normal(len, 0.0, 0.01);
        let w = g.vec_normal(len, 0.0, 0.5);
        let u = vec![1.0f32; len];
        let mut imp = vec![0.0f32; len];
        let mut prev = usize::MAX;
        for thr in [0.001f32, 0.01, 0.1, 1.0] {
            let mut mask = BitMask::zeros(len);
            score_and_mask(&grad, &w, &u, thr, EPS, &mut imp, &mut mask);
            assert!(mask.count() <= prev);
            prev = mask.count();
        }
    });
}

// ---- recovery algebra (DESIGN.md §15) ----------------------------------

/// Small engine config shared by the elastic-membership properties.
fn elastic_cfg(spec: &str, nodes: usize, seed: u64) -> SimCfg {
    SimCfg {
        nodes,
        method: MethodSpec::parse(spec).expect("registry spec"),
        link: LinkSpec::new(1e9, 0.0),
        seed,
        steps_per_epoch: 2,
        warmup_epochs: 0,
        chaos: None,
        ..Default::default()
    }
}

fn elastic_layout() -> ParamLayout {
    ParamLayout::new(
        "elastic",
        vec![
            ("bn".into(), vec![16], LayerKind::BatchNorm),
            ("fc".into(), vec![64, 10], LayerKind::Fc),
        ],
    )
}

#[test]
fn survivor_handoff_matches_a_fresh_smaller_ring_given_the_state() {
    // The re-ring contract (DESIGN.md §15): crashing node k out of an
    // n-ring under handoff must leave survivors bit-identical to a
    // *fresh* (n−1)-ring that was handed the survivor state directly —
    // departing store merged into the post-removal ring successor at
    // slot k % (n−1). If the two ever diverge by a bit, recovery has
    // hidden state the migration seam does not capture.
    forall("handoff == fresh (n-1)-ring + handed state", 20, |g| {
        let n = g.usize_in(3, 6);
        let node = g.usize_in(0, n);
        let len = g.usize_in(16, 200);
        let layout = ParamLayout::new("h", vec![("fc".into(), vec![len], LayerKind::Fc)]);
        let spec_name = g.choice(&["iwp:fixed", "iwp:layerwise", "dgc:topk"]);
        let spec = MethodSpec::parse(spec_name).unwrap();
        let sc = |nodes: usize| StageCfg {
            nodes,
            state_nodes: nodes,
            threshold: 0.05,
            beta: 0.002,
            c: 1.0,
            mask_nodes: nodes.min(2),
            random_select: false,
            momentum: 0.9,
            dgc_density: 0.05,
            warmup_epochs: 0,
        };
        // Two accumulations so the velocity state is non-trivial too —
        // merge_from folds both res and vel, and a handoff that dropped
        // velocity would still pass a pending-only single-step check.
        let stores: Vec<ResidualStore> = (0..n)
            .map(|_| {
                let mut s = ResidualStore::new(len, 0.9);
                s.accumulate(&g.vec_normal(len, 0.0, 1.0));
                s.accumulate(&g.vec_normal(len, 0.0, 1.0));
                s
            })
            .collect();
        let mut crashed = pipeline::build(spec, &sc(n), &layout);
        for (i, s) in stores.iter().enumerate() {
            crashed.install_node(i, s.clone());
        }
        crashed.remove_node(node, RecoveryMode::Handoff, n - 1, n - 1);

        let mut handed = stores;
        let departing = handed.remove(node);
        let succ = node % (n - 1);
        handed[succ].merge_from(&departing);
        let mut fresh = pipeline::build(spec, &sc(n - 1), &layout);
        for (i, s) in handed.into_iter().enumerate() {
            fresh.install_node(i, s);
        }

        for i in 0..n - 1 {
            let a = crashed.pending(i).expect("stateful pipeline");
            let b = fresh.pending(i).expect("stateful pipeline");
            for (j, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{spec_name} n={n} crash@{node}: node {i} coord {j} ({x} vs {y})"
                );
            }
        }
    });
}

#[test]
fn drop_and_rescale_preserves_gradient_mass() {
    // DropRescale replaces the departed node's contribution by scaling
    // every survivor by N/(N−1) in f32. Two guarantees, both documented
    // in DESIGN.md §15: (a) per coordinate the survivor's pending value
    // is *bitwise* old * (N as f32 / (N−1) as f32) — one f32 multiply,
    // replicated here exactly; (b) the f64 sum of survivors therefore
    // lands on (Σbefore − departed)·N/(N−1) to within one rounding step
    // per coordinate, bounded by 1e-4·(1 + Σ|pending|).
    forall("rescale: per-coord bitwise, sums to tolerance", 10, |g| {
        let n = 4; // == SimEngine::SIM_NODE_CAP: every member has a store
        let node = g.usize_in(0, n);
        let steps = g.usize_in(1, 4);
        let spec = g.choice(&["iwp:fixed", "dgc:topk"]);
        let mut e = SimEngine::new(elastic_layout(), elastic_cfg(spec, n, 42 + g.case as u64));
        for s in 0..steps {
            e.step(s);
        }
        let before: Vec<Vec<f32>> =
            (0..n).map(|i| e.pending(i).expect("stateful").to_vec()).collect();
        e.remove_node(node, RecoveryMode::DropRescale);

        let factor = n as f32 / (n - 1) as f32;
        let mut sum_after = 0.0f64;
        let mut scale = 0.0f64;
        for i in 0..n - 1 {
            let pre = &before[if i < node { i } else { i + 1 }];
            let post = e.pending(i).expect("stateful");
            for (j, (&x, &y)) in pre.iter().zip(post).enumerate() {
                assert_eq!(
                    (x * factor).to_bits(),
                    y.to_bits(),
                    "{spec} crash@{node}: node {i} coord {j} not a single f32 rescale"
                );
                sum_after += y as f64;
                scale += x.abs() as f64;
            }
        }
        let sum_before: f64 = (0..n)
            .filter(|&i| i != node)
            .map(|i| before[i].iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        let expect = sum_before * (n as f64) / ((n - 1) as f64);
        let tol = 1e-4 * (1.0 + scale);
        assert!(
            (sum_after - expect).abs() <= tol,
            "{spec} crash@{node}: Σafter {sum_after} vs {expect} (tol {tol})"
        );
    });
}

#[test]
fn join_after_warmup_never_resurrects_stale_residuals() {
    // A mid-run join materializes a zeroed store: bit-exact zeros for
    // the newcomer, survivors untouched bit for bit, and the enlarged
    // ring keeps stepping. The ring had already finished its warm-up
    // schedule — a resurrection bug would show up as non-zero pending
    // on the joiner (stale state from a previous member) right here.
    for spec in ["iwp:fixed", "dgc:topk"] {
        // nodes = 3 < SIM_NODE_CAP so the join materializes a 4th store.
        let mut c = elastic_cfg(spec, 3, 42);
        c.warmup_epochs = 2;
        let mut e = SimEngine::new(elastic_layout(), c);
        for s in 0..5 {
            e.step(s); // epochs 0–1 are warm-up; step 4 is past it
        }
        let before: Vec<Vec<u32>> = (0..3)
            .map(|i| e.pending(i).expect("stateful").iter().map(|v| v.to_bits()).collect())
            .collect();
        e.add_node(5);
        let joined = e.pending(3).expect("joiner store materialized");
        assert!(
            joined.iter().all(|&v| v.to_bits() == 0),
            "{spec}: joiner resurrected stale residuals"
        );
        for (i, bits) in before.iter().enumerate() {
            let now = e.pending(i).expect("stateful");
            assert!(
                now.iter().map(|v| v.to_bits()).eq(bits.iter().copied()),
                "{spec}: join perturbed survivor {i}"
            );
        }
        let r = e.step(5);
        assert!(
            r.density.is_finite() && r.seconds > 0.0,
            "{spec}: enlarged ring failed to step"
        );
    }
}

#[test]
fn ring_time_dominated_by_slowest_round() {
    // Latency model: K rounds with per-round max semantics.
    forall("round time == max link", 30, |g| {
        let n = g.usize_in(2, 8);
        let spec = LinkSpec::new(1e6, 0.001);
        let mut nw = RingNet::new(n, spec, 1.0);
        let bytes: Vec<u64> = (0..n).map(|_| g.usize_in(0, 100_000) as u64).collect();
        let dur = nw.round(&bytes);
        let expect = bytes
            .iter()
            .map(|&b| spec.transfer_time(b))
            .fold(0.0f64, f64::max);
        assert!((dur - expect).abs() < 1e-12);
    });
}
