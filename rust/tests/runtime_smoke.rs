//! Integration: PJRT runtime against the real AOT artifacts.
//! Requires `make artifacts` (skipped gracefully if absent).

use ringiwp::compress::importance as cpu_imp;
use ringiwp::runtime::{ImportanceKernel, Runtime};
use ringiwp::sparse::BitMask;
use ringiwp::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::cpu(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn importance_kernel_matches_cpu_mirror() {
    let Some(rt) = runtime() else { return };
    let mut kernel = ImportanceKernel::load(&rt).expect("load kernel");
    let mut rng = Rng::new(7);
    // Odd length forces the padded-tail path (not a multiple of 8192).
    for len in [1000usize, 8192, 20_000] {
        let mut g = vec![0.0f32; len];
        let mut w = vec![0.0f32; len];
        rng.fill_normal(&mut g, 0.0, 0.1);
        rng.fill_normal(&mut w, 0.0, 1.0);
        let u = vec![1.0f32; len];
        let thr = 0.05f32;
        let eps = 1e-8f32;

        let (mask_k, imp_k, stats_k) =
            kernel.score(&g, &w, &u, thr, eps).expect("kernel score");

        let mut imp_c = vec![0.0f32; len];
        let mut mask_c = BitMask::zeros(len);
        let stats_c =
            cpu_imp::score_and_mask(&g, &w, &u, thr, eps, &mut imp_c, &mut mask_c);

        assert_eq!(mask_k, mask_c, "mask mismatch at len={len}");
        for i in 0..len {
            assert!(
                (imp_k[i] - imp_c[i]).abs() <= 1e-5 * imp_c[i].abs().max(1.0),
                "imp[{i}] {} vs {}",
                imp_k[i],
                imp_c[i]
            );
        }
        assert_eq!(stats_k.n, stats_c.n);
        assert_eq!(stats_k.n_selected, stats_c.n_selected);
        assert!((stats_k.sum - stats_c.sum).abs() < 1e-2 * stats_c.sum.abs().max(1.0));
    }
}

#[test]
fn mlp_train_step_runs_and_learns() {
    let Some(rt) = runtime() else { return };
    let art = rt.load("train_step_mlp_b32").expect("load mlp");
    let layout = art.meta.layout().expect("layout");
    assert_eq!(art.meta.n_param_inputs().unwrap(), 6);

    // He-init params.
    let mut rng = Rng::new(1);
    let mut params: Vec<Vec<f32>> = layout
        .layers()
        .iter()
        .map(|l| {
            let mut p = vec![0.0f32; l.size];
            if l.shape.len() == 2 {
                let sigma = (2.0 / l.shape[0] as f32).sqrt();
                rng.fill_normal(&mut p, 0.0, sigma);
            }
            p
        })
        .collect();

    let data = ringiwp::data::SynthClassification::cifar_like(3);
    let mut data_rng = Rng::new(5);
    let (x, y) = data.batch(&mut data_rng, 32);

    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..30 {
        let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        inputs.push(&x);
        inputs.push(&y);
        let out = art.run_f32(&inputs).expect("run");
        // outputs: loss, acc, grads...
        let loss = out[0][0];
        assert!(loss.is_finite());
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        for (p, g) in params.iter_mut().zip(&out[2..]) {
            for (pi, gi) in p.iter_mut().zip(g) {
                *pi -= 0.05 * gi;
            }
        }
    }
    assert!(
        last_loss < first_loss.unwrap() * 0.7,
        "loss did not decrease: {} -> {last_loss}",
        first_loss.unwrap()
    );
}

#[test]
fn tfm_train_step_shapes() {
    let Some(rt) = runtime() else { return };
    let art = rt.load("train_step_tfm_tiny_b8").expect("load tfm");
    let layout = art.meta.layout().expect("layout");
    let n_params: usize = layout.total_params();
    assert!(n_params > 300_000 && n_params < 600_000, "{n_params}");

    let mut rng = Rng::new(2);
    let params: Vec<Vec<f32>> = layout
        .layers()
        .iter()
        .map(|l| {
            let mut p = vec![0.0f32; l.size];
            match l.kind {
                ringiwp::model::LayerKind::Norm => p.fill(1.0),
                ringiwp::model::LayerKind::Bias => {}
                _ => {
                    let sigma = 1.0 / (l.fan_in() as f32).sqrt();
                    rng.fill_normal(&mut p, 0.0, sigma);
                }
            }
            p
        })
        .collect();

    let corpus = ringiwp::data::CharCorpus::tiny();
    let mut drng = Rng::new(3);
    let tokens = corpus.batch(&mut drng, 8, 64);

    let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    inputs.push(&tokens);
    let out = art.run_f32(&inputs).expect("run tfm");
    let loss = out[0][0];
    // Random init: loss ~ ln(96) = 4.56.
    assert!(
        (loss - 4.56).abs() < 1.0,
        "initial loss {loss} far from ln(vocab)"
    );
    assert_eq!(out.len(), 1 + layout.n_layers());
    for (g, l) in out[1..].iter().zip(layout.layers()) {
        assert_eq!(g.len(), l.size);
        assert!(g.iter().all(|v| v.is_finite()));
    }
}
