//! Integration: the full trainer across every method on the real MLP
//! artifact + simulated ring. Requires `make artifacts`.

use ringiwp::compress::Method;
use ringiwp::config::Config;
use ringiwp::coordinator::Trainer;
use ringiwp::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::cpu(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn cfg(method: Method, steps: usize) -> Config {
    spec_cfg(method.spec(), steps)
}

fn spec_cfg(method: ringiwp::compress::MethodSpec, steps: usize) -> Config {
    Config {
        method,
        steps,
        nodes: 4,
        model: "mlp".into(),
        steps_per_epoch: 20,
        warmup_epochs: 1,
        seed: 7,
        // Early-training importance on a fresh small model is O(1-10)
        // (large CE gradients vs He-init weights), so the IWP threshold
        // is correspondingly larger than the paper's ImageNet
        // steady-state 0.005-0.1 range.
        threshold: 200.0,
        ..Config::default()
    }
}

#[test]
fn baseline_mlp_learns() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(cfg(Method::Baseline, 40), &rt).unwrap();
    let out = t.run().unwrap();
    let first = out.losses[0].1;
    let last = out.losses.last().unwrap().1;
    assert!(last < first * 0.6, "loss {first} -> {last}");
    // Dense ratio is ~1 by construction.
    assert!((out.account.ratio() - 1.0).abs() < 0.05, "{}", out.account.ratio());
    assert!(out.final_eval_acc > 0.5, "acc {}", out.final_eval_acc);
}

#[test]
fn iwp_fixed_compresses_and_learns() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(cfg(Method::IwpFixed, 40), &rt).unwrap();
    let out = t.run().unwrap();
    let first = out.losses[0].1;
    let last = out.losses.last().unwrap().1;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(
        out.account.ratio() > 3.0,
        "expected compression, ratio {}",
        out.account.ratio()
    );
    assert!(
        out.account.payload_ratio() > out.account.ratio(),
        "payload metric should exceed wire metric"
    );
}

#[test]
fn iwp_layerwise_compresses_and_learns() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(cfg(Method::IwpLayerwise, 40), &rt).unwrap();
    let out = t.run().unwrap();
    let last = out.losses.last().unwrap().1;
    assert!(last < out.losses[0].1 * 0.8);
    assert!(out.account.ratio() > 2.0, "{}", out.account.ratio());
    assert!(out.account.mean_density() < 0.4);
}

#[test]
fn dgc_runs_on_ring() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(cfg(Method::Dgc, 30), &rt).unwrap();
    let out = t.run().unwrap();
    assert!(out.losses.last().unwrap().1.is_finite());
    assert!(out.account.ratio() > 1.0);
}

#[test]
fn terngrad_runs_and_learns() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(cfg(Method::TernGrad, 40), &rt).unwrap();
    let out = t.run().unwrap();
    let last = out.losses.last().unwrap().1;
    assert!(last < out.losses[0].1, "loss did not decrease");
    assert!(out.account.ratio() > 2.0, "{}", out.account.ratio());
}

#[test]
fn new_compositions_train_end_to_end() {
    // The compressor-subsystem compositions (DESIGN.md §12) through the
    // real trainer: variance-gated IWP, DGC transport under layerwise
    // thresholds, and the ternary-payload stage.
    let Some(rt) = runtime() else { return };
    for spec in ["iwp:vargate", "dgc:layerwise", "iwp:fixed+tern"] {
        let spec = ringiwp::compress::MethodSpec::parse(spec).unwrap();
        let mut t = Trainer::new(spec_cfg(spec, 30), &rt).unwrap();
        let out = t.run().unwrap();
        assert!(
            out.losses.last().unwrap().1.is_finite(),
            "{spec}: loss diverged"
        );
        assert!(out.account.ratio() > 1.0, "{spec}: {}", out.account.ratio());
        assert!(out.account.mean_density() < 1.0, "{spec}");
    }
}

#[test]
fn trainer_replays_bit_identically_for_a_fixed_spec() {
    // Two trainers built from the same spec must replay identical
    // losses and accounting, bit for bit — pins the pipeline's state
    // init and RNG routing as deterministic at the trainer level.
    // (Alias == canonical-spec equivalence is a *parse-time* property:
    // `MethodSpec::parse("iwp-fixed") == parse("iwp:fixed")` is pinned
    // by the spec.rs unit tests and `tests/compressor_equivalence.rs`,
    // so both would reach this constructor as the same value.)
    let Some(rt) = runtime() else { return };
    let spec = ringiwp::compress::MethodSpec::parse("iwp:fixed").unwrap();
    let out_a = Trainer::new(cfg(Method::IwpFixed, 20), &rt)
        .unwrap()
        .run()
        .unwrap();
    let out_b = Trainer::new(spec_cfg(spec, 20), &rt).unwrap().run().unwrap();
    let bits = |v: &[(usize, f64)]| -> Vec<(usize, u64)> {
        v.iter().map(|&(s, l)| (s, l.to_bits())).collect()
    };
    assert_eq!(bits(&out_a.losses), bits(&out_b.losses));
    assert_eq!(
        out_a.account.total_wire_bytes(),
        out_b.account.total_wire_bytes()
    );
}

#[test]
fn iwp_beats_baseline_bandwidth_at_similar_loss() {
    let Some(rt) = runtime() else { return };
    let out_base = Trainer::new(cfg(Method::Baseline, 60), &rt)
        .unwrap()
        .run()
        .unwrap();
    let out_iwp = Trainer::new(cfg(Method::IwpFixed, 60), &rt)
        .unwrap()
        .run()
        .unwrap();
    // The paper's central claim at miniature scale: large byte savings,
    // small accuracy/loss cost.
    let bytes_base = out_base.account.total_wire_bytes();
    let bytes_iwp = out_iwp.account.total_wire_bytes();
    assert!(
        (bytes_base as f64) / (bytes_iwp as f64) > 3.0,
        "bandwidth saving too small: {bytes_base} vs {bytes_iwp}"
    );
    assert!(
        out_iwp.final_eval_loss < out_base.final_eval_loss * 1.5,
        "IWP loss {} vs baseline {}",
        out_iwp.final_eval_loss,
        out_base.final_eval_loss
    );
}
