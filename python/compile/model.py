"""L2 facade: the jitted functions that become AOT artifacts.

Each builder returns (fn, example_args, manifest_meta).  `aot.py` lowers
fn via jax.jit(...).lower(*example_args) to HLO text and writes the
manifest JSON the rust runtime uses to marshal Literals.

The importance artifact is an L2 function that *calls the L1 Pallas
kernel*, so the kernel lowers into the same HLO module (three-layer
chain: rust -> this HLO -> pallas ops).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from compile.kernels import importance as iwp_kernel
from compile.models import mlp, transformer


def _shape_meta(shape_dtype_structs, names):
    return [
        {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
        for n, s in zip(names, shape_dtype_structs)
    ]


def _layer_meta(layers):
    out, offset = [], 0
    for name, shape, kind in layers:
        size = 1
        for d in shape:
            size *= d
        out.append(
            {
                "name": name,
                "shape": list(shape),
                "kind": kind,
                "size": size,
                "offset": offset,
            }
        )
        offset += size
    return out


def build_importance(m: int):
    """Importance kernel over a flat f32[m] buffer (m % CHUNK == 0)."""

    def fn(g, w, u, thr, eps):
        return iwp_kernel.importance_prune(g, w, u, thr, eps, interpret=True)

    f32 = jnp.float32
    import jax

    args = (
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((1,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )
    meta = {
        "kind": "importance",
        "m": m,
        "chunk": iwp_kernel.CHUNK,
        "inputs": _shape_meta(args, ["g", "w", "u", "thr", "eps"]),
        "outputs": [
            {"name": "mask", "shape": [m], "dtype": "float32"},
            {"name": "importance", "shape": [m], "dtype": "float32"},
            {"name": "stats", "shape": [iwp_kernel.N_STATS], "dtype": "float32"},
        ],
    }
    return fn, args, meta


def build_mlp_train_step(batch_size: int):
    def fn(*flat):
        params, (x, y) = list(flat[:-2]), flat[-2:]
        return mlp.train_step(params, x, y)

    params, x, y = mlp.example_args(batch_size)
    args = (*params, x, y)
    names = [n for n, _, _ in mlp.LAYERS] + ["x", "y"]
    meta = {
        "kind": "train_step",
        "model": "mlp",
        "batch_size": batch_size,
        "inputs": _shape_meta(args, names),
        "outputs": (
            [
                {"name": "loss", "shape": [], "dtype": "float32"},
                {"name": "acc", "shape": [], "dtype": "float32"},
            ]
            + [
                {"name": "grad." + n, "shape": list(s), "dtype": "float32"}
                for n, s, _ in mlp.LAYERS
            ]
        ),
        "layers": _layer_meta(mlp.LAYERS),
    }
    return fn, args, meta


def build_tfm_train_step(preset: str, batch_size: int):
    cfg = transformer.PRESETS[preset]
    layers = transformer.layer_spec(cfg)

    def fn(*flat):
        params, tokens = list(flat[:-1]), flat[-1]
        return transformer.train_step(params, tokens, cfg)

    params, tokens = transformer.example_args(cfg, batch_size)
    args = (*params, tokens)
    names = [n for n, _, _ in layers] + ["tokens"]
    meta = {
        "kind": "train_step",
        "model": f"tfm_{preset}",
        "batch_size": batch_size,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "n_params": transformer.n_params(cfg),
        "inputs": _shape_meta(args, names),
        "outputs": (
            [{"name": "loss", "shape": [], "dtype": "float32"}]
            + [
                {"name": "grad." + n, "shape": list(s), "dtype": "float32"}
                for n, s, _ in layers
            ]
        ),
        "layers": _layer_meta(layers),
    }
    return fn, args, meta
