"""Pure-jnp oracle for the importance-pruning kernel.

This is the unfused reference the Pallas kernel is validated against
(pytest `test_kernel.py`): same math, written the naive multi-pass way a
GPU implementation of the paper would run it (score pass, mask pass,
stats pass).  Numerics must match the kernel to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

N_STATS = 4


def importance_scores(g, w, eps):
    """I = |g| / (|w| + eps) — Sec. III-B's gradient importance."""
    return jnp.abs(g) / (jnp.abs(w) + eps)


def prune_mask(imp, u, thr):
    """Randomized threshold: u==1 -> hard threshold, u~U[0,1) -> P=I/thr."""
    return (imp > u * thr).astype(jnp.float32)


def layer_stats(imp, mask):
    """[sum I, sum I^2, n_selected, n_total] — inputs to Eq. 4."""
    return jnp.stack(
        [
            jnp.sum(imp),
            jnp.sum(imp * imp),
            jnp.sum(mask),
            jnp.float32(imp.shape[-1]),
        ]
    )


def importance_prune_ref(g, w, u, thr, eps):
    """Reference pipeline; mirrors kernels.importance.importance_prune."""
    imp = importance_scores(g, w, eps[0])
    mask = prune_mask(imp, u, thr[0])
    return mask, imp, layer_stats(imp, mask)
