"""L1 — Pallas kernel: fused importance-weighted pruning mask.

The paper's per-parameter hot spot (Sec. III-B/C): for every parameter,

    importance I = |g| / (|w| + eps)            (the "ratio of parameter
                                                 calculation gradient to
                                                 parameter value")
    transmit    = I > thr                        (fixed / layerwise thr)
    or, with random gradient selection (Sec. III-C),
    transmit    = u < I / thr    with u ~ U[0,1)  => P(update) = I/thr

Both cases collapse to one branch-free compare:

    mask = (I > u * thr)

because u == 1.0 recovers the plain threshold and u ~ U[0,1) gives the
randomized acceptance (I > thr implies I > u*thr for any u < 1).

TPU adaptation (DESIGN.md §7): the GPU paper would run three elementwise
kernels (score, compact, histogram).  Here everything is fused into ONE
VMEM pass per 8192-element chunk — one HBM read of (g, w, u), one HBM
write of (mask, I), plus per-chunk Σ/Σ² partials that feed the Eq. 4
layer-wise threshold controller, so the layer statistics never require a
second pass over HBM.  Masks are emitted as f32 0/1 (no cheap u8 vector
path on the VPU); the wire encoding to bitmaps happens in L3 where bytes
actually matter.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact executes
on the rust CPU client.  Real-TPU perf is estimated structurally in
DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk of the flat parameter/gradient buffer processed per grid step.
# 5 live f32 buffers x 32 KiB = 160 KiB << 16 MiB VMEM (double-buffer room).
CHUNK = 8192

# Number of per-chunk statistics emitted for the layerwise controller:
# [sum(I), sum(I^2), n_selected, n_total]
N_STATS = 4


def _iwp_kernel(thr_ref, eps_ref, g_ref, w_ref, u_ref, mask_ref, imp_ref, stats_ref):
    """One VMEM-resident chunk: score + mask + stats in a single pass."""
    g = g_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    thr = thr_ref[0]
    eps = eps_ref[0]

    imp = jnp.abs(g) / (jnp.abs(w) + eps)
    # Branch-free randomized threshold (see module docstring).
    mask = (imp > u * thr).astype(jnp.float32)

    imp_ref[...] = imp
    mask_ref[...] = mask
    # Per-chunk partial sums for the Eq. 4 layerwise controller — each grid
    # step owns one row of the (n_chunks, N_STATS) output, so the layer
    # statistics come out of the same single HBM pass as the mask.
    stats_ref[0, 0] = jnp.sum(imp)
    stats_ref[0, 1] = jnp.sum(imp * imp)
    stats_ref[0, 2] = jnp.sum(mask)
    stats_ref[0, 3] = jnp.float32(imp.shape[-1])


@functools.partial(jax.jit, static_argnames=("interpret",))
def importance_prune(g, w, u, thr, eps, *, interpret: bool = True):
    """Fused importance scoring over a flat f32 buffer.

    Args:
      g:   f32[M]  flat gradient (M must be a multiple of CHUNK)
      w:   f32[M]  flat parameter values
      u:   f32[M]  uniform randoms in [0,1) (pass 1.0 to disable the
                   random-selection path and get the hard threshold)
      thr: f32[1]  importance threshold
      eps: f32[1]  denominator guard

    Returns:
      mask:  f32[M]       1.0 = transmit, 0.0 = accumulate locally
      imp:   f32[M]       importance scores |g|/(|w|+eps)
      stats: f32[4]       [sum I, sum I^2, n_selected, n_total] over M
    """
    (m,) = g.shape
    if m % CHUNK != 0:
        raise ValueError(f"buffer length {m} not a multiple of CHUNK={CHUNK}")
    n_chunks = m // CHUNK

    mask, imp, stats = pl.pallas_call(
        _iwp_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),      # thr (broadcast)
            pl.BlockSpec((1,), lambda i: (0,)),      # eps (broadcast)
            pl.BlockSpec((CHUNK,), lambda i: (i,)),  # g
            pl.BlockSpec((CHUNK,), lambda i: (i,)),  # w
            pl.BlockSpec((CHUNK,), lambda i: (i,)),  # u
        ],
        out_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),         # mask
            pl.BlockSpec((CHUNK,), lambda i: (i,)),         # importance
            pl.BlockSpec((1, N_STATS), lambda i: (i, 0)),   # per-chunk stats
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((n_chunks, N_STATS), jnp.float32),
        ],
        interpret=interpret,
    )(thr, eps, g, w, u)
    # Tiny tree-reduction over the per-chunk rows (n_chunks x 4 values).
    return mask, imp, jnp.sum(stats, axis=0)
