"""L2 — decoder-only char-LM transformer (pre-LN, causal).

The second real model for end-to-end validation: `examples/
train_transformer.rs` trains it for a few hundred steps on the embedded
tiny corpus over a simulated ring with IWP compression and logs the loss
curve (EXPERIMENTS.md §E2E).

Sizes are presets so the same artifact pipeline scales from ~0.4M params
(CI-friendly on 1 CPU core) up to ~25M ("base", ResNet50-class parameter
count) on real hardware.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int = 96
    seq_len: int = 64
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


PRESETS = {
    # ~0.42M params — default e2e driver (1 CPU core budget).
    "tiny": TfmConfig(vocab=96, seq_len=64, d_model=128, n_layers=2, n_heads=4, d_ff=512),
    # ~3.2M params — heavier local runs.
    "small": TfmConfig(vocab=96, seq_len=128, d_model=256, n_layers=4, n_heads=8, d_ff=1024),
    # ~25M params — ResNet50-class count; for real hardware.
    "base": TfmConfig(vocab=96, seq_len=256, d_model=512, n_layers=8, n_heads=8, d_ff=2048),
}


def layer_spec(cfg: TfmConfig):
    """(name, shape, kind) for every parameter, in artifact order."""
    layers = [
        ("embed.weight", (cfg.vocab, cfg.d_model), "embed"),
        ("pos.weight", (cfg.seq_len, cfg.d_model), "embed"),
    ]
    for i in range(cfg.n_layers):
        p = f"block{i}."
        layers += [
            (p + "ln1.gain", (cfg.d_model,), "norm"),
            (p + "ln1.bias", (cfg.d_model,), "bias"),
            (p + "attn.wq", (cfg.d_model, cfg.d_model), "attn"),
            (p + "attn.wk", (cfg.d_model, cfg.d_model), "attn"),
            (p + "attn.wv", (cfg.d_model, cfg.d_model), "attn"),
            (p + "attn.wo", (cfg.d_model, cfg.d_model), "attn"),
            (p + "ln2.gain", (cfg.d_model,), "norm"),
            (p + "ln2.bias", (cfg.d_model,), "bias"),
            (p + "mlp.w1", (cfg.d_model, cfg.d_ff), "fc"),
            (p + "mlp.b1", (cfg.d_ff,), "bias"),
            (p + "mlp.w2", (cfg.d_ff, cfg.d_model), "fc"),
            (p + "mlp.b2", (cfg.d_model,), "bias"),
        ]
    layers += [
        ("lnf.gain", (cfg.d_model,), "norm"),
        ("lnf.bias", (cfg.d_model,), "bias"),
        ("head.weight", (cfg.d_model, cfg.vocab), "fc"),
    ]
    return layers


def n_params(cfg: TfmConfig) -> int:
    total = 0
    for _, shape, _ in layer_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def init_params(key, cfg: TfmConfig):
    params = []
    for name, shape, kind in layer_spec(cfg):
        key, sub = jax.random.split(key)
        if kind == "norm":
            params.append(jnp.ones(shape, jnp.float32))
        elif kind == "bias" or name.endswith(".bias"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            scale = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layer_norm(x, gain, bias, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return gain * (x - mu) / jnp.sqrt(var + eps) + bias


def _attention(x, wq, wk, wv, wo, cfg: TfmConfig):
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(proj):
        return proj.reshape(b, t, h, dh).transpose(0, 2, 1, 3)  # b h t dh

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dh))
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(causal[None, None] > 0, scores, -1e9)
    attn = jax.nn.softmax(scores, -1)
    out = (attn @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def forward(params, tokens, cfg: TfmConfig):
    """tokens: i32[B, T] -> logits f32[B, T, vocab]."""
    it = iter(params)
    embed, pos = next(it), next(it)
    x = embed[tokens] + pos[None, : tokens.shape[1]]
    for _ in range(cfg.n_layers):
        g1, b1 = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        g2, b2 = next(it), next(it)
        mw1, mb1, mw2, mb2 = next(it), next(it), next(it), next(it)
        x = x + _attention(_layer_norm(x, g1, b1), wq, wk, wv, wo, cfg)
        h = _layer_norm(x, g2, b2)
        x = x + jax.nn.relu(h @ mw1 + mb1) @ mw2 + mb2
    gf, bf = next(it), next(it)
    head = next(it)
    return _layer_norm(x, gf, bf) @ head


def loss_fn(params, inputs, targets, cfg: TfmConfig):
    logits = forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def train_step(params, tokens_f32, cfg: TfmConfig):
    """tokens_f32: f32[B, T+1] (cast inside; rust marshals f32 only).
    inputs = tokens[:, :T], targets = tokens[:, 1:].  Returns (loss, *grads)."""
    tokens = tokens_f32.astype(jnp.int32)
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    loss, grads = jax.value_and_grad(loss_fn)(params, inputs, targets, cfg)
    return (loss, *grads)


def example_args(cfg: TfmConfig, batch_size: int):
    f32 = jnp.float32
    params = [jax.ShapeDtypeStruct(s, f32) for _, s, _ in layer_spec(cfg)]
    tokens = jax.ShapeDtypeStruct((batch_size, cfg.seq_len + 1), f32)
    return params, tokens
