"""L2 — MLP image classifier (the paper's AlexNet-class stand-in).

A 3-layer ReLU MLP over flattened 32x32x3 inputs, 10 classes — the small
real model whose end-to-end training (PJRT from rust, N-node simulated
ring) produces the *accuracy* columns of Table I and the Fig. 5/6 curves.
The *ratio* columns run on the true AlexNet/ResNet50 layer inventories in
rust (DESIGN.md §2).

The train step is a single jitted function (loss, accuracy, grads) that
AOT-lowers to one HLO artifact; parameters travel as a flat list of arrays
so the rust side can treat them uniformly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

IN_DIM = 3 * 32 * 32
HIDDEN1 = 256
HIDDEN2 = 128
N_CLASSES = 10

# (name, shape, kind) — kind feeds the layerwise controller, mirroring the
# paper's conv/bn/fc distinction.
LAYERS = [
    ("fc1.weight", (IN_DIM, HIDDEN1), "fc"),
    ("fc1.bias", (HIDDEN1,), "bias"),
    ("fc2.weight", (HIDDEN1, HIDDEN2), "fc"),
    ("fc2.bias", (HIDDEN2,), "bias"),
    ("fc3.weight", (HIDDEN2, N_CLASSES), "fc"),
    ("fc3.bias", (N_CLASSES,), "bias"),
]


def init_params(key):
    """He-init params as the flat list the artifact expects."""
    params = []
    for name, shape, _kind in LAYERS:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            scale = jnp.sqrt(2.0 / shape[0])
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    return h @ w3 + b3  # logits


def loss_fn(params, x, y_onehot):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    acc = jnp.mean(
        (jnp.argmax(logits, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32)
    )
    return loss, acc


def train_step(params, x, y_f32):
    """One local step: inputs all f32 (labels cast inside — keeps the rust
    Literal marshalling single-dtype).  Returns (loss, acc, *grads)."""
    y = y_f32.astype(jnp.int32)
    y_onehot = jax.nn.one_hot(y, N_CLASSES, dtype=jnp.float32)
    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, x, y_onehot
    )
    return (loss, acc, *grads)


def example_args(batch_size: int):
    f32 = jnp.float32
    params = [jax.ShapeDtypeStruct(s, f32) for _, s, _ in LAYERS]
    x = jax.ShapeDtypeStruct((batch_size, IN_DIM), f32)
    y = jax.ShapeDtypeStruct((batch_size,), f32)
    return params, x, y
