"""AOT lowering: jitted L2 functions -> artifacts/*.hlo.txt (+ manifests).

Interchange is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly
(/opt/xla-example/README.md).  Lowered with return_tuple=True; the rust
side unwraps with to_tuple().

Run once via `make artifacts`; python never appears on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, args, meta) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = dict(meta)
    meta["name"] = name
    meta["hlo"] = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  {name}: {len(text)} chars, {len(meta['inputs'])} inputs")


# (name, builder) — the artifact set the rust runtime expects.
ARTIFACTS = {
    "importance_m65536": lambda: model.build_importance(65536),
    "importance_m8192": lambda: model.build_importance(8192),
    "train_step_mlp_b32": lambda: model.build_mlp_train_step(32),
    "train_step_tfm_tiny_b8": lambda: model.build_tfm_train_step("tiny", 8),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma list of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = list(ARTIFACTS) if not args.only else args.only.split(",")
    print(f"lowering {len(names)} artifacts -> {args.out}")
    for name in names:
        fn, ex_args, meta = ARTIFACTS[name]()
        emit(args.out, name, fn, ex_args, meta)
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"artifacts": names}, f, indent=1)
    print("done")


if __name__ == "__main__":
    main()
