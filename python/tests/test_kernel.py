"""L1 correctness: Pallas kernel vs pure-jnp oracle.

The CORE correctness signal for the compile path: hypothesis sweeps
buffer sizes, value ranges, and thresholds; every case asserts
allclose(kernel, ref) plus the semantic invariants of the paper's
mask (Sec. III-B/C).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.importance import CHUNK, N_STATS, importance_prune


def _mk(key, m, scale_g=1.0, scale_w=1.0):
    kg, kw, ku = jax.random.split(key, 3)
    g = scale_g * jax.random.normal(kg, (m,), jnp.float32)
    w = scale_w * jax.random.normal(kw, (m,), jnp.float32)
    u = jax.random.uniform(ku, (m,), jnp.float32)
    return g, w, u


@settings(max_examples=12, deadline=None)
@given(
    n_chunks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    thr=st.sampled_from([0.005, 0.01, 0.05, 0.1, 1.0]),
    scale_g=st.sampled_from([1e-3, 1.0, 10.0]),
)
def test_kernel_matches_ref(n_chunks, seed, thr, scale_g):
    m = n_chunks * CHUNK
    g, w, u = _mk(jax.random.PRNGKey(seed), m, scale_g=scale_g)
    thr_a = jnp.array([thr], jnp.float32)
    eps_a = jnp.array([1e-8], jnp.float32)
    mask_k, imp_k, stats_k = importance_prune(g, w, u, thr_a, eps_a)
    mask_r, imp_r, stats_r = ref.importance_prune_ref(g, w, u, thr_a, eps_a)
    np.testing.assert_allclose(imp_k, imp_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(mask_k, mask_r)
    np.testing.assert_allclose(stats_k, stats_r, rtol=1e-4)


def test_hard_threshold_when_u_is_one():
    m = CHUNK
    g, w, _ = _mk(jax.random.PRNGKey(0), m)
    u = jnp.ones((m,), jnp.float32)
    thr = jnp.array([0.05], jnp.float32)
    eps = jnp.array([1e-8], jnp.float32)
    mask, imp, _ = importance_prune(g, w, u, thr, eps)
    np.testing.assert_array_equal(mask, (imp > 0.05).astype(jnp.float32))


def test_random_selection_rate():
    """P(update) = importance/threshold for sub-threshold gradients."""
    m = 4 * CHUNK
    key = jax.random.PRNGKey(7)
    # Construct importance exactly 0.5*thr everywhere -> expect ~50% selected.
    thr = 0.1
    g = jnp.full((m,), 0.05, jnp.float32)
    w = jnp.full((m,), 1.0, jnp.float32)
    u = jax.random.uniform(key, (m,), jnp.float32)
    mask, _, stats = importance_prune(
        g, w, u, jnp.array([thr], jnp.float32), jnp.array([0.0], jnp.float32)
    )
    rate = float(stats[2] / stats[3])
    assert abs(rate - 0.5) < 0.02, rate


def test_stats_are_sums_over_all_chunks():
    m = 3 * CHUNK
    g, w, u = _mk(jax.random.PRNGKey(3), m)
    thr = jnp.array([0.01], jnp.float32)
    eps = jnp.array([1e-8], jnp.float32)
    mask, imp, stats = importance_prune(g, w, u, thr, eps)
    assert stats.shape == (N_STATS,)
    np.testing.assert_allclose(stats[0], jnp.sum(imp), rtol=1e-5)
    np.testing.assert_allclose(stats[2], jnp.sum(mask), rtol=1e-6)
    assert float(stats[3]) == m


def test_rejects_non_chunk_multiple():
    bad = jnp.zeros((CHUNK + 1,), jnp.float32)
    one = jnp.array([1.0], jnp.float32)
    with pytest.raises(ValueError):
        importance_prune(bad, bad, bad, one, one)


def test_zero_weights_guarded_by_eps():
    m = CHUNK
    g = jnp.ones((m,), jnp.float32)
    w = jnp.zeros((m,), jnp.float32)
    u = jnp.ones((m,), jnp.float32)
    mask, imp, _ = importance_prune(
        g, w, u, jnp.array([1.0], jnp.float32), jnp.array([1e-8], jnp.float32)
    )
    assert bool(jnp.all(jnp.isfinite(imp)))
    assert bool(jnp.all(mask == 1.0))  # |1|/eps >> thr
