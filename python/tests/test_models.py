"""L2 correctness: model shapes, gradient sanity, causality, learning."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.models import mlp, transformer


def test_mlp_train_step_shapes():
    params = mlp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, mlp.IN_DIM), jnp.float32)
    y = jnp.array([0.0, 1.0, 2.0, 3.0], jnp.float32)
    out = mlp.train_step(params, x, y)
    loss, acc, grads = out[0], out[1], out[2:]
    assert loss.shape == () and acc.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_mlp_learns_constant_labels():
    params = mlp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, mlp.IN_DIM), jnp.float32)
    y = jnp.zeros((32,), jnp.float32)
    step = jax.jit(mlp.train_step)
    first = None
    for _ in range(30):
        out = step(params, x, y)
        loss, grads = out[0], out[2:]
        if first is None:
            first = float(loss)
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert float(loss) < first * 0.5, (first, float(loss))


def test_tfm_param_count_tiny():
    cfg = transformer.PRESETS["tiny"]
    n = transformer.n_params(cfg)
    assert 3e5 < n < 6e5, n


def test_tfm_forward_shapes():
    cfg = transformer.PRESETS["tiny"]
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len), 0, cfg.vocab)
    logits = transformer.forward(params, toks, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_tfm_causality():
    """Changing a future token must not change past logits."""
    cfg = transformer.PRESETS["tiny"]
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.seq_len), 0, cfg.vocab)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    l1 = transformer.forward(params, toks, cfg)
    l2 = transformer.forward(params, toks2, cfg)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_tfm_train_step_grads():
    cfg = transformer.PRESETS["tiny"]
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (2, cfg.seq_len + 1), 0, cfg.vocab
    ).astype(jnp.float32)
    out = transformer.train_step(params, toks, cfg)
    loss, grads = out[0], out[1:]
    assert len(grads) == len(params)
    # Initial loss should be near ln(vocab) for random params.
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads)
