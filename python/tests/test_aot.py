"""AOT pipeline sanity: manifests match builder shapes; layers contiguous."""

import jax.numpy as jnp

from compile import model


def _nelem(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def test_importance_manifest():
    fn, args, meta = model.build_importance(16384)
    assert meta["m"] == 16384 and meta["chunk"] == 8192
    assert [i["name"] for i in meta["inputs"]] == ["g", "w", "u", "thr", "eps"]
    out = fn(*[jnp.zeros(a.shape, jnp.float32) + 0.5 for a in args])
    assert [list(o.shape) for o in out] == [o["shape"] for o in meta["outputs"]]


def test_mlp_manifest_layers_contiguous():
    _fn, _args, meta = model.build_mlp_train_step(8)
    off = 0
    for layer in meta["layers"]:
        assert layer["offset"] == off
        assert layer["size"] == _nelem(layer["shape"])
        off += layer["size"]
    total = off
    assert total == sum(_nelem(s) for _, s, _ in __import__(
        "compile.models.mlp", fromlist=["LAYERS"]).LAYERS)


def test_tfm_manifest_consistent():
    _fn, args, meta = model.build_tfm_train_step("tiny", 2)
    assert meta["n_params"] == sum(l["size"] for l in meta["layers"])
    assert len(meta["inputs"]) == len(meta["layers"]) + 1
    # grads mirror params one-to-one
    assert len(meta["outputs"]) == 1 + len(meta["layers"])
    for layer, out in zip(meta["layers"], meta["outputs"][1:]):
        assert out["shape"] == layer["shape"]
